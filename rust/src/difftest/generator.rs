//! Seeded, deterministic RV64IMAC guest-program generator.
//!
//! Programs are generated as a *structured* [`TestProgram`] — a list of
//! basic blocks with explicit terminators — rather than raw bytes, so that
//! (a) termination is guaranteed by construction (control flow only goes
//! forward, except bounded counted loops), and (b) the shrinker
//! (`crate::difftest::shrink_program`) can remove blocks and instructions
//! while keeping the program well-formed.
//!
//! The generated body exercises: 64/32-bit ALU ops, multiply/divide
//! (including divide-by-zero and overflow operands), compressed encodings
//! (emitted as raw 16-bit words through `isa::encode`'s C-extension
//! helpers), loads/stores of every width with deliberate aliasing inside a
//! small hot window, AMOs and LR/SC pairs, CSR reads/writes, SBI console
//! ecalls, forward conditional branches, direct and indirect jumps, counted
//! back-edges, and blocks deliberately placed to straddle 4 KiB page
//! boundaries (stressing the DBT's cross-page translation guard).
//!
//! ## Register discipline
//!
//! The comparison in the differential driver covers the *entire* register
//! file, so every register must end a run with an engine-independent value:
//!
//! * pool registers (`a0-a5`, `t0-t2`, `s2-s4`) — free for body items;
//! * `s0` — private-scratch base (`scratch + mhartid * PRIV_BYTES`);
//! * `sp` — second private window (`s0 + 1024`) for SP-relative compressed
//!   forms;
//! * `s1` — counted-loop register (0 outside loop bodies);
//! * `t3-t6`, `ra`, `gp` — harness scratch, reset to engine-independent
//!   values before exit;
//! * everything else is never written.
//!
//! Multi-hart programs run the same body on every hart over disjoint
//! private windows (so per-hart register files stay schedule-independent),
//! then contend on a shared LR/SC spinlock + AMO counters; any register
//! that could carry a schedule-dependent value is zeroed before the exit
//! barrier.

use crate::asm::{
    Assembler, Image, A0, A1, A2, A3, A4, A5, A7, GP, RA, S0, S1, S2, S3, S4, SP, T0, T1, T2, T3,
    T4, T5, T6, ZERO,
};
use crate::isa::csr::{CSR_INSTRET, CSR_MHARTID, CSR_MSCRATCH, CSR_MTVAL, CSR_MTVEC, CSR_SSCRATCH};
use crate::isa::encode;
use crate::isa::op::*;
use crate::prop::Rng;

/// Per-hart private scratch stride: 1 KiB addressed off `s0` plus 1 KiB
/// addressed off `sp`.
pub const PRIV_BYTES: u64 = 2048;
const PRIV_SHIFT: i32 = 11;
const SP_WINDOW_OFF: i32 = 1024;
/// Hot window (bytes) for s0-relative accesses — small so that accesses of
/// different widths alias the same bytes often.
const HOT_WINDOW: u64 = 96;

/// Registers the body may freely overwrite.
pub const POOL: &[u8] = &[A0, A1, A2, A3, A4, A5, T0, T1, T2, S2, S3, S4];
/// Compressed-form destination registers (must be x8-x15 *and* in POOL).
const CPOOL: &[u8] = &[A0, A1, A2, A3, A4, A5];
/// Registers the body may read but not write.
const READ_EXTRA: &[u8] = &[S0, ZERO];

/// One straight-line body instruction (or short fixed sequence).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Item {
    /// A 32-bit base-ISA instruction with fixed operands (no control flow;
    /// loads/stores address `off(s0)`).
    Op(Op),
    /// A raw compressed encoding (SP-relative forms address the `sp`
    /// window, others `s0`/registers).
    C(u16),
    /// `addi t3, s0, off` + the AMO on `(t3)`.
    Amo { op: AmoOp, wide: bool, rd: u8, rs2: u8, off: i32 },
    /// `addi t3, s0, off` + `lr` + immediately-succeeding `sc`.
    LrSc { wide: bool, rd_lr: u8, rd_sc: u8, rs2: u8, off: i32 },
    /// SBI console putchar: `li a7, 1; li a0, ch; ecall`.
    Putchar(u8),
}

impl Item {
    /// Number of guest instructions this item expands to (shrink-report
    /// accounting).
    pub fn insts(&self) -> usize {
        match self {
            Item::Op(_) | Item::C(_) => 1,
            Item::Amo { .. } => 2,
            Item::LrSc { .. } => 3,
            Item::Putchar(_) => 3,
        }
    }
}

/// How a block ends. Every terminator compiles to *explicit* control flow
/// (no implicit fall-through), so blocks can be freely reordered/removed
/// and padding can be inserted between them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Term {
    /// `j next`
    Next,
    /// `bCC rs1, rs2, blocks[target]` (forward; clamped to the epilogue),
    /// else `j next`.
    Skip { cond: BrCond, rs1: u8, rs2: u8, target: usize },
    /// `li s1, count` before the body; `addi s1, s1, -1; bnez s1, top;
    /// j next` after it.
    Loop { count: u8 },
    /// `la t4, next; jr t4` — exercises indirect-jump chaining.
    IndirectNext,
}

/// A generated basic block.
#[derive(Debug, Clone)]
pub struct Block {
    /// When `Some(k)`, pad with zero bytes until the block starts `k`
    /// bytes *before* a 4 KiB page boundary (k even, small), so its first
    /// instructions straddle the boundary.
    pub page_pad: Option<u32>,
    pub items: Vec<Item>,
    pub term: Term,
}

/// A complete generated guest program.
#[derive(Debug, Clone)]
pub struct TestProgram {
    pub seed: u64,
    pub harts: usize,
    /// Initial values materialised into the pool registers.
    pub reg_seed: Vec<(u8, u64)>,
    pub blocks: Vec<Block>,
    /// Shared-memory contention rounds per hart (multi-hart only).
    pub contention_rounds: u32,
}

/// Deliberate mis-assembly used to validate that the differential harness
/// actually catches divergence (the engines run the sabotaged image, the
/// reference simulator runs the clean one).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BugInjection {
    None,
    /// Emit every body `xor`/`xori` as `or`/`ori` — models a DBT/decoder
    /// mismatch on one opcode.
    XorBecomesOr,
}

/// Layout facts the differential driver needs for memory comparison.
pub struct Assembled {
    pub image: Image,
    /// Base physical address of the shared cells (lock / counter / AMO
    /// counter / done flag — 32 bytes).
    pub shared: u64,
    /// Base physical address of the private scratch windows.
    pub scratch: u64,
    /// Total scratch length (`harts * PRIV_BYTES`).
    pub scratch_len: usize,
}

// ---------------------------------------------------------------------------
// Generation
// ---------------------------------------------------------------------------

fn pick_reg(r: &mut Rng) -> u8 {
    *r.pick(POOL)
}

fn pick_read_reg(r: &mut Rng) -> u8 {
    if r.below(8) == 0 {
        *r.pick(READ_EXTRA)
    } else {
        pick_reg(r)
    }
}

/// Aligned offset inside the hot window for a `width`-byte access.
fn hot_off(r: &mut Rng, width: u64) -> i32 {
    (width * r.below(HOT_WINDOW / width)) as i32
}

fn gen_alu(r: &mut Rng) -> Item {
    let rd = pick_reg(r);
    let rs1 = pick_read_reg(r);
    let rs2 = pick_read_reg(r);
    match r.below(4) {
        0 => {
            // register-register ALU
            let op = *r.pick(&[
                AluOp::Add,
                AluOp::Sub,
                AluOp::Sll,
                AluOp::Slt,
                AluOp::Sltu,
                AluOp::Xor,
                AluOp::Srl,
                AluOp::Sra,
                AluOp::Or,
                AluOp::And,
            ]);
            let word = matches!(op, AluOp::Add | AluOp::Sub | AluOp::Sll | AluOp::Srl | AluOp::Sra)
                && r.bool();
            Item::Op(Op::Alu { op, word, rd, rs1, rs2 })
        }
        1 => {
            // immediate ALU (no Sub immediate form)
            let op = *r.pick(&[
                AluOp::Add,
                AluOp::Slt,
                AluOp::Sltu,
                AluOp::Xor,
                AluOp::Or,
                AluOp::And,
                AluOp::Sll,
                AluOp::Srl,
                AluOp::Sra,
            ]);
            let word = matches!(op, AluOp::Add | AluOp::Sll | AluOp::Srl | AluOp::Sra) && r.bool();
            let imm = match op {
                AluOp::Sll | AluOp::Srl | AluOp::Sra => {
                    if word {
                        r.below(32) as i32
                    } else {
                        r.below(64) as i32
                    }
                }
                _ => r.range_i64(-2048, 2047) as i32,
            };
            Item::Op(Op::AluImm { op, word, rd, rs1, imm })
        }
        2 => {
            // M extension, including div/rem by (possibly) zero
            let op = *r.pick(&[
                MulOp::Mul,
                MulOp::Mulh,
                MulOp::Mulhsu,
                MulOp::Mulhu,
                MulOp::Div,
                MulOp::Divu,
                MulOp::Rem,
                MulOp::Remu,
            ]);
            let word = matches!(op, MulOp::Mul | MulOp::Div | MulOp::Divu | MulOp::Rem | MulOp::Remu)
                && r.bool();
            Item::Op(Op::Mul { op, word, rd, rs1, rs2 })
        }
        _ => Item::Op(Op::Lui { rd, imm: ((r.range_i64(-(1 << 19), (1 << 19) - 1) as i32) << 12) }),
    }
}

fn gen_mem(r: &mut Rng) -> Item {
    let widths = [MemWidth::B, MemWidth::H, MemWidth::W, MemWidth::D];
    let width = *r.pick(&widths);
    let off = hot_off(r, width.bytes());
    if r.bool() {
        let signed = width == MemWidth::D || r.bool();
        Item::Op(Op::Load { width, signed, rd: pick_reg(r), rs1: S0, imm: off })
    } else {
        Item::Op(Op::Store { width, rs1: S0, rs2: pick_read_reg(r), imm: off })
    }
}

fn gen_compressed(r: &mut Rng) -> Item {
    let crd = *r.pick(CPOOL);
    let crs2 = *r.pick(&[A0, A1, A2, A3, A4, A5, S0, S1]);
    let rd = pick_reg(r);
    let imm6 = r.range_i64(-32, 31) as i32;
    let enc = match r.below(12) {
        0 => encode::c_addi(rd, imm6),
        1 => encode::c_addiw(rd, imm6),
        2 => encode::c_li(rd, imm6),
        3 => {
            let nz = if imm6 == 0 { 1 } else { imm6 };
            encode::c_lui(crd, nz)
        }
        4 => encode::c_andi(crd, imm6),
        5 => match r.below(3) {
            0 => encode::c_srli(crd, r.below(63) as u32 + 1),
            1 => encode::c_srai(crd, r.below(63) as u32 + 1),
            _ => encode::c_slli(rd, r.below(63) as u32 + 1),
        },
        6 => match r.below(6) {
            0 => encode::c_sub(crd, crs2),
            1 => encode::c_xor(crd, crs2),
            2 => encode::c_or(crd, crs2),
            3 => encode::c_and(crd, crs2),
            4 => encode::c_subw(crd, crs2),
            _ => encode::c_addw(crd, crs2),
        },
        7 => {
            if r.bool() {
                encode::c_mv(rd, crs2.max(1))
            } else {
                encode::c_add(rd, crs2.max(1))
            }
        }
        8 => {
            // s0-relative compressed load
            if r.bool() {
                encode::c_lw(crd, S0, hot_off(r, 4) as u32)
            } else {
                encode::c_ld(crd, S0, hot_off(r, 8) as u32)
            }
        }
        9 => {
            // s0-relative compressed store
            if r.bool() {
                encode::c_sw(crs2, S0, hot_off(r, 4) as u32)
            } else {
                encode::c_sd(crs2, S0, hot_off(r, 8) as u32)
            }
        }
        10 => {
            // sp-relative (second private window)
            let imm4 = (4 * r.below(24)) as u32;
            let imm8 = (8 * r.below(24)) as u32;
            match r.below(4) {
                0 => encode::c_lwsp(rd, imm4),
                1 => encode::c_ldsp(rd, imm8),
                2 => encode::c_swsp(*r.pick(POOL), imm4),
                _ => encode::c_sdsp(*r.pick(POOL), imm8),
            }
        }
        _ => encode::c_addi4spn(crd, (4 * (1 + r.below(120))) as u32),
    };
    Item::C(enc)
}

fn gen_csr(r: &mut Rng) -> Item {
    let rd = pick_reg(r);
    if r.below(3) == 0 {
        // Stable read-only / counter reads. CYCLE/TIME are deliberately
        // excluded: their values are timing-model-dependent, which is
        // exactly the kind of legitimate divergence the functional
        // comparison must not observe.
        let csr = *r.pick(&[CSR_MHARTID, CSR_INSTRET]);
        Item::Op(Op::Csr { op: CsrOp::Rs, imm_form: false, rd, rs1: ZERO, csr })
    } else {
        let csr = *r.pick(&[CSR_MSCRATCH, CSR_SSCRATCH, CSR_MTVAL]);
        let op = *r.pick(&[CsrOp::Rw, CsrOp::Rs, CsrOp::Rc]);
        let imm_form = r.bool();
        let rs1 = if imm_form { r.below(32) as u8 } else { pick_reg(r) };
        Item::Op(Op::Csr { op, imm_form, rd, rs1, csr })
    }
}

fn gen_amo(r: &mut Rng) -> Item {
    let wide = r.bool();
    let width = if wide { 8 } else { 4 };
    Item::Amo {
        op: *r.pick(&[
            AmoOp::Swap,
            AmoOp::Add,
            AmoOp::Xor,
            AmoOp::And,
            AmoOp::Or,
            AmoOp::Min,
            AmoOp::Max,
            AmoOp::Minu,
            AmoOp::Maxu,
        ]),
        wide,
        rd: if r.below(4) == 0 { ZERO } else { pick_reg(r) },
        rs2: pick_read_reg(r),
        off: hot_off(r, width),
    }
}

fn gen_item(r: &mut Rng, multi: bool) -> Item {
    match r.below(20) {
        0..=6 => gen_alu(r),
        7..=9 => gen_mem(r),
        10..=12 => gen_compressed(r),
        13..=14 => gen_csr(r),
        15 => gen_amo(r),
        16 => {
            let wide = r.bool();
            let width = if wide { 8 } else { 4 };
            Item::LrSc {
                wide,
                rd_lr: pick_reg(r),
                rd_sc: pick_reg(r),
                rs2: pick_read_reg(r),
                off: hot_off(r, width),
            }
        }
        17 if !multi => Item::Putchar(b'a' + (r.below(26) as u8)),
        _ => gen_alu(r),
    }
}

fn gen_term(r: &mut Rng, index: usize, num_blocks: usize) -> Term {
    match r.below(10) {
        0..=3 => Term::Next,
        4..=5 => {
            // forward skip; target past the next block, clamped to the
            // epilogue at assembly
            let remaining = num_blocks - index; // >= 1
            let target = index + 1 + r.below(remaining as u64 + 1) as usize;
            let cond =
                *r.pick(&[BrCond::Eq, BrCond::Ne, BrCond::Lt, BrCond::Ge, BrCond::Ltu, BrCond::Geu]);
            Term::Skip { cond, rs1: pick_read_reg(r), rs2: pick_read_reg(r), target }
        }
        6..=7 => Term::Loop { count: 2 + r.below(5) as u8 },
        _ => Term::IndirectNext,
    }
}

/// Generate the program for `seed`.
pub fn generate(seed: u64, harts: usize) -> TestProgram {
    let mut r = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    // Register seeds draw from a forked sub-stream, so their values do not
    // shift whenever the block generator's draw count changes.
    let mut reg_rng = r.fork(0x5EED_5EED);
    let multi = harts > 1;
    let num_blocks = 2 + r.below(6) as usize;
    let blocks = (0..num_blocks)
        .map(|i| {
            let n_items = 2 + r.below(9);
            Block {
                page_pad: if i > 0 && r.chance(14) {
                    Some(*r.pick(&[0u32, 2, 4, 6]))
                } else {
                    None
                },
                items: (0..n_items).map(|_| gen_item(&mut r, multi)).collect(),
                term: gen_term(&mut r, i, num_blocks),
            }
        })
        .collect();
    TestProgram {
        seed,
        harts,
        reg_seed: POOL.iter().map(|&reg| (reg, reg_rng.interesting_u64())).collect(),
        blocks,
        contention_rounds: if multi { 8 + r.below(24) as u32 } else { 0 },
    }
}

// ---------------------------------------------------------------------------
// Assembly
// ---------------------------------------------------------------------------

fn invert(cond: BrCond) -> BrCond {
    match cond {
        BrCond::Eq => BrCond::Ne,
        BrCond::Ne => BrCond::Eq,
        BrCond::Lt => BrCond::Ge,
        BrCond::Ge => BrCond::Lt,
        BrCond::Ltu => BrCond::Geu,
        BrCond::Geu => BrCond::Ltu,
    }
}

/// Apply the bug injection to a body op.
fn sabotage(op: Op, bug: BugInjection) -> Op {
    if bug == BugInjection::XorBecomesOr {
        match op {
            Op::Alu { op: AluOp::Xor, word, rd, rs1, rs2 } => {
                return Op::Alu { op: AluOp::Or, word, rd, rs1, rs2 };
            }
            Op::AluImm { op: AluOp::Xor, word, rd, rs1, imm } => {
                return Op::AluImm { op: AluOp::Or, word, rd, rs1, imm };
            }
            _ => {}
        }
    }
    op
}

impl TestProgram {
    /// Assemble into a flat image. `bug` sabotages *body* instructions
    /// only — the harness (prologue/epilogue/trap handler) always
    /// assembles faithfully, so an injected bug is only observable through
    /// generated code, the way a real decoder bug would be.
    pub fn assemble(&self, bug: BugInjection) -> Assembled {
        let mut a = Assembler::new(crate::mem::DRAM_BASE);
        let n = self.blocks.len();
        let labels: Vec<_> = (0..=n).map(|_| a.new_label()).collect();
        let trap_l = a.new_label();
        let shared_l = a.new_label();
        let scratch_l = a.new_label();

        // ---- prologue ----------------------------------------------------
        a.la(T0, trap_l);
        a.csrw(CSR_MTVEC, T0);
        a.csrr(T6, CSR_MHARTID);
        a.slli(T5, T6, PRIV_SHIFT);
        a.la(S0, scratch_l);
        a.add(S0, S0, T5);
        a.addi(SP, S0, SP_WINDOW_OFF);
        a.li(S1, 0);
        for &(reg, value) in &self.reg_seed {
            a.li(reg, value as i64);
        }
        a.j(labels[0]);

        // ---- body blocks -------------------------------------------------
        for (i, block) in self.blocks.iter().enumerate() {
            if let Some(offs) = block.page_pad {
                while (a.pc() + offs as u64) % 4096 != 0 {
                    a.d8(0);
                }
            }
            a.bind(labels[i]);
            let loop_top = match block.term {
                Term::Loop { count } => {
                    a.li(S1, count as i64);
                    Some(a.here())
                }
                _ => None,
            };
            for item in &block.items {
                match *item {
                    Item::Op(op) => a.emit(sabotage(op, bug)),
                    Item::C(enc) => a.emit_raw16(enc),
                    Item::Amo { op, wide, rd, rs2, off } => {
                        let width = if wide { MemWidth::D } else { MemWidth::W };
                        a.addi(T3, S0, off);
                        a.emit(Op::Amo { op, width, rd, rs1: T3, rs2 });
                    }
                    Item::LrSc { wide, rd_lr, rd_sc, rs2, off } => {
                        let width = if wide { MemWidth::D } else { MemWidth::W };
                        a.addi(T3, S0, off);
                        a.emit(Op::Lr { width, rd: rd_lr, rs1: T3 });
                        a.emit(Op::Sc { width, rd: rd_sc, rs1: T3, rs2 });
                    }
                    Item::Putchar(ch) => {
                        a.li(A7, 1);
                        a.li(A0, ch as i64);
                        a.ecall();
                    }
                }
            }
            let next = labels[i + 1];
            match block.term {
                Term::Next => a.j(next),
                Term::Skip { cond, rs1, rs2, target } => {
                    // Inverted branch over a long-range `j`, so padded
                    // blocks stay reachable regardless of distance.
                    let over = a.new_label();
                    a.branch(invert(cond), rs1, rs2, over);
                    a.j(labels[target.min(n)]);
                    a.bind(over);
                    a.j(next);
                }
                Term::Loop { .. } => {
                    a.addi(S1, S1, -1);
                    a.bnez(S1, loop_top.expect("loop top bound above"));
                    a.j(next);
                }
                Term::IndirectNext => {
                    a.la(T4, next);
                    a.jr(T4);
                }
            }
        }

        // ---- epilogue ----------------------------------------------------
        a.bind(labels[n]);
        if self.harts > 1 {
            // Shared-memory contention: LR/SC spinlock protecting a plain
            // increment, plus an AMO side counter. Layout: lock at
            // shared+0, locked counter at shared+8, AMO counter at
            // shared+16, done flag at shared+24.
            a.la(T3, shared_l);
            a.li(T5, self.contention_rounds as i64);
            let round = a.here();
            let acquire = a.here();
            a.lr_w(T6, T3);
            a.bnez(T6, acquire);
            a.li(RA, 1);
            a.sc_w(T6, RA, T3);
            a.bnez(T6, acquire);
            a.lw(GP, T3, 8);
            a.addi(GP, GP, 1);
            a.sw(GP, T3, 8);
            a.fence();
            a.amoswap_w(ZERO, ZERO, T3); // release the lock
            a.addi(T4, T3, 16);
            a.amoadd_w(ZERO, RA, T4);
            a.addi(T5, T5, -1);
            a.bnez(T5, round);
            // Zero everything whose final value depends on the schedule.
            a.li(GP, 0);
            a.li(T6, 0);
            a.li(RA, 0);
        }
        // Completion barrier: bump the done flag, park non-zero harts in a
        // single-instruction self-loop, hart 0 waits for every hart then
        // exits with a register-fold signature.
        //
        // Ordering matters for cross-engine determinism: every register
        // must hold its final value *before* the done-flag AMO, and the
        // only instruction after the AMO is the self-branch itself. A
        // sibling hart can then be frozen (by hart 0's exit) at any point
        // after its AMO and still present exactly the parked pc/registers,
        // regardless of how the engine interleaved the final instructions.
        a.csrr(T5, CSR_MHARTID);
        a.la(T3, shared_l);
        a.addi(T3, T3, 24);
        a.li(T4, 1);
        a.li(T6, 0);
        a.amoadd_w(ZERO, T4, T3);
        let park = a.here();
        a.bnez(T5, park);
        a.li(T6, self.harts as i64);
        let wait = a.here();
        a.lw(T4, T3, 0);
        a.blt(T4, T6, wait);
        for &reg in &POOL[1..] {
            a.xor(A0, A0, reg);
        }
        a.li(A7, 93);
        a.ecall();

        // ---- trap handler ------------------------------------------------
        a.align(4);
        a.bind(trap_l);
        a.csrr(A0, crate::isa::csr::CSR_MCAUSE);
        a.addi(A0, A0, 100);
        a.li(A7, 93);
        a.ecall();

        // ---- data --------------------------------------------------------
        a.align(64);
        let shared = a.pc();
        a.bind(shared_l);
        a.d64(0); // +0  lock
        a.d64(0); // +8  locked counter
        a.d64(0); // +16 AMO counter
        a.d64(0); // +24 done flag
        a.align(64);
        let scratch = a.pc();
        a.bind(scratch_l);
        a.zero_fill(self.harts * PRIV_BYTES as usize);

        Assembled {
            image: a.finish(),
            shared,
            scratch,
            scratch_len: self.harts * PRIV_BYTES as usize,
        }
    }

    /// Total body instructions (the size the shrinker minimises).
    pub fn body_insts(&self) -> usize {
        self.blocks.iter().map(|b| b.items.iter().map(Item::insts).sum::<usize>()).sum()
    }

    /// Human-readable listing of the body, with compressed encodings
    /// disassembled through their expanded form.
    pub fn listing(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "seed {:#x}, {} hart(s), {} block(s), {} body instruction(s):",
            self.seed,
            self.harts,
            self.blocks.len(),
            self.body_insts()
        );
        for (i, block) in self.blocks.iter().enumerate() {
            let pad = match block.page_pad {
                Some(k) => format!(" (page boundary - {} bytes)", k),
                None => String::new(),
            };
            let _ = writeln!(s, "block {}{}:", i, pad);
            for item in &block.items {
                match *item {
                    Item::Op(op) => {
                        let _ = writeln!(s, "    {}", op);
                    }
                    Item::C(enc) => {
                        let _ = writeln!(s, "    c.{:04x}  ({})", enc, crate::isa::decode16(enc));
                    }
                    Item::Amo { op, wide, rd, rs2, off } => {
                        let width = if wide { MemWidth::D } else { MemWidth::W };
                        let _ = writeln!(s, "    addi t3, s0, {}", off);
                        let _ = writeln!(s, "    {}", Op::Amo { op, width, rd, rs1: T3, rs2 });
                    }
                    Item::LrSc { wide, rd_lr, rd_sc, rs2, off } => {
                        let width = if wide { MemWidth::D } else { MemWidth::W };
                        let _ = writeln!(s, "    addi t3, s0, {}", off);
                        let _ = writeln!(s, "    {}", Op::Lr { width, rd: rd_lr, rs1: T3 });
                        let _ = writeln!(s, "    {}", Op::Sc { width, rd: rd_sc, rs1: T3, rs2 });
                    }
                    Item::Putchar(ch) => {
                        let _ = writeln!(s, "    putchar '{}'", ch as char);
                    }
                }
            }
            let _ = writeln!(s, "    -> {:?}", block.term);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = generate(42, 1);
        let b = generate(42, 1);
        assert_eq!(a.blocks.len(), b.blocks.len());
        assert_eq!(a.reg_seed, b.reg_seed);
        for (x, y) in a.blocks.iter().zip(b.blocks.iter()) {
            assert_eq!(x.items, y.items);
            assert_eq!(x.term, y.term);
        }
        // Different seeds diverge.
        let c = generate(43, 1);
        let same = a.blocks.len() == c.blocks.len()
            && a.reg_seed == c.reg_seed
            && a.blocks.iter().zip(c.blocks.iter()).all(|(x, y)| x.items == y.items);
        assert!(!same, "seed must select the program");
    }

    #[test]
    fn assembly_is_reproducible_and_loads() {
        for seed in 0..20 {
            for harts in [1usize, 2] {
                let prog = generate(seed, harts);
                let a = prog.assemble(BugInjection::None);
                let b = prog.assemble(BugInjection::None);
                assert_eq!(a.image.bytes, b.image.bytes, "seed {}", seed);
                assert_eq!(a.scratch, b.scratch);
                assert!(a.scratch_len == harts * PRIV_BYTES as usize);
                assert!(a.image.bytes.len() > 64);
            }
        }
    }

    #[test]
    fn sabotage_only_changes_xor_sites() {
        // Find a seed whose body contains a 32-bit xor; its sabotaged
        // image must differ, and a xor-free program's must not.
        let mut found = false;
        for seed in 0..200 {
            let prog = generate(seed, 1);
            let has_xor = prog.blocks.iter().flat_map(|b| &b.items).any(|i| {
                matches!(
                    i,
                    Item::Op(Op::Alu { op: AluOp::Xor, .. })
                        | Item::Op(Op::AluImm { op: AluOp::Xor, .. })
                )
            });
            let clean = prog.assemble(BugInjection::None);
            let bad = prog.assemble(BugInjection::XorBecomesOr);
            assert_eq!(clean.image.bytes == bad.image.bytes, !has_xor, "seed {}", seed);
            found |= has_xor;
        }
        assert!(found, "corpus must contain xor sites");
    }

    #[test]
    fn listing_mentions_blocks() {
        let prog = generate(7, 1);
        let l = prog.listing();
        assert!(l.contains("block 0"));
        assert!(l.contains("body instruction"));
    }
}
