//! Differential co-simulation fuzzer.
//!
//! The paper's validation story (§4.1) rests on the DBT engine agreeing
//! with an independent cycle-level reference. This module turns that
//! one-off validation into a continuous, randomized harness: a seeded
//! generator ([`generator`]) emits self-contained RV64IMAC guest images,
//! and every execution engine — the naive interpreter, the lockstep DBT
//! engine, and the multi-threaded parallel engine — runs the same image
//! and is cross-checked against the reference simulator
//! ([`crate::refsim::RefSim`]), which shares only the instruction
//! semantics layer (`sys::exec`) with the engines under test: fetch,
//! translation, scheduling, caching and timing are all independent.
//!
//! Checked per seed:
//!
//! 1. **End state vs the reference** for each engine: exit code, the full
//!    register file, pc, privilege, key CSRs, retired-instruction counts
//!    (single-hart), console output, and all guest memory the program can
//!    dirty (private scratch windows + shared cells).
//! 2. **Per-instruction lockstep** (single-hart): the interpreter and the
//!    reference are stepped one instruction at a time and compared after
//!    every step — the first diverging instruction is reported directly.
//! 3. **Per-block lockstep** (single-hart): the DBT engine runs one
//!    translated block at a time and the interpreter is advanced by the
//!    same number of retired instructions, pinning divergence to a block.
//! 4. **Cycle cross-check** (single-hart): the DBT InOrder pipeline's
//!    cycle count must stay within a configurable tolerance of the
//!    reference's — a smoke-level guard against gross timing-accounting
//!    regressions (the tight <1% claim is validated on the structured
//!    workloads, see `refsim::validate_inorder_quick`).
//!
//! A failing seed is reduced by [`shrink_program`] — block removal, item
//! removal, terminator simplification, register-seed dropping — to a
//! minimal body that still diverges, printed with `isa::disasm`.

pub mod generator;

pub use generator::{BugInjection, TestProgram};

use crate::coordinator::{build_system, EngineMode, SimConfig};
use crate::engine::{ExecutionEngine, ExitReason};
use crate::fiber::FiberEngine;
use crate::interp::InterpEngine;
use crate::isa::disasm::REG_NAMES;
use crate::mem::PhysMem;
use crate::refsim::RefSim;
use crate::sys::loader::load_flat;
use crate::sys::{Hart, SystemSnapshot};
use generator::{Assembled, Term};
use std::fmt;

/// Differential-run configuration.
#[derive(Debug, Clone)]
pub struct DiffConfig {
    pub harts: usize,
    /// Memory model for the reference and the serial engines (the
    /// parallel engine always runs atomic, per Table 2).
    pub memory: String,
    /// Pipeline model for the lockstep DBT engine.
    pub pipeline: String,
    /// Per-engine instruction budget; generated programs terminate well
    /// under this, so hitting it is itself reported as a divergence.
    pub max_insts: u64,
    /// Run the per-instruction and per-block lockstep comparisons
    /// (single-hart only).
    pub lockstep: bool,
    /// Cross-check DBT cycles against the reference. Only applied on
    /// single-hart runs under the *atomic* memory model: with a timing
    /// memory model the reference charges every access while the DBT
    /// filters through the L0, so their cycle counts legitimately drift.
    pub check_cycles: bool,
    /// Relative cycle tolerance (fraction of the reference count).
    pub cycle_rel_tol: f64,
    /// Absolute cycle slack added on top of the relative tolerance.
    pub cycle_abs_tol: u64,
    /// DBT backend for the lockstep/sharded engines under test (the
    /// micro-op interpreter, or natively emitted x86-64 code).
    pub backend: crate::dbt::Backend,
}

impl DiffConfig {
    pub fn new(harts: usize) -> DiffConfig {
        DiffConfig {
            harts,
            // Multi-hart runs default to MESI so coherence-driven L0
            // flushes are part of the checked surface.
            memory: if harts > 1 { "mesi".into() } else { "atomic".into() },
            pipeline: "inorder".into(),
            max_insts: 2_000_000,
            lockstep: true,
            // The individual cycle checks gate themselves on hart count
            // and model (inorder-vs-reference needs one hart; the dynamic
            // band runs at any width), so the master switch defaults on.
            check_cycles: true,
            cycle_rel_tol: 0.75,
            cycle_abs_tol: 5_000,
            backend: crate::dbt::Backend::default(),
        }
    }
}

/// One confirmed divergence.
#[derive(Debug, Clone)]
pub struct Divergence {
    pub seed: u64,
    /// Which comparison failed (engine name, or a check label like
    /// `interp(step)` / `lockstep(cycles)`).
    pub engine: String,
    pub detail: String,
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed {:#x} [{}]: {}", self.seed, self.engine, self.detail)
    }
}

fn div(seed: u64, engine: &str, detail: String) -> Divergence {
    Divergence { seed, engine: engine.into(), detail }
}

// ---------------------------------------------------------------------------
// State capture and comparison
// ---------------------------------------------------------------------------

/// Guest-visible end state, captured uniformly from every engine.
struct State {
    harts: Vec<Hart>,
    exit: Option<u64>,
    console: Vec<u8>,
    shared: Vec<u8>,
    scratch: Vec<u8>,
}

impl State {
    fn from_snapshot(snap: &SystemSnapshot, layout: &Assembled) -> State {
        State {
            harts: snap.harts.clone(),
            exit: snap.exit,
            console: snap.console.clone(),
            shared: snap.phys.read_bytes(layout.shared, 32),
            scratch: snap.phys.read_bytes(layout.scratch, layout.scratch_len),
        }
    }

    fn from_refsim(rsim: &RefSim, layout: &Assembled) -> State {
        let mut harts = rsim.harts.clone();
        SystemSnapshot::normalize_harts(&mut harts);
        State {
            harts,
            exit: rsim.sys.exit.or(rsim.sys.bus.simio.exit_code),
            console: rsim.sys.bus.uart.output.clone(),
            shared: rsim.sys.phys.read_bytes(layout.shared, 32),
            scratch: rsim.sys.phys.read_bytes(layout.scratch, layout.scratch_len),
        }
    }

    /// First difference between the reference (`self`) and an engine
    /// (`other`), if any. `instret` is only compared on single-hart runs —
    /// parked sibling harts legitimately retire a schedule-dependent
    /// number of park-loop iterations.
    fn diff(&self, other: &State, compare_instret: bool) -> Option<String> {
        if self.exit != other.exit {
            return Some(format!(
                "exit latch: reference {:?} vs engine {:?}",
                self.exit, other.exit
            ));
        }
        for (h, (a, b)) in self.harts.iter().zip(other.harts.iter()).enumerate() {
            if let Some(msg) = diff_hart(a, b, compare_instret) {
                return Some(format!("hart {}: {}", h, msg));
            }
        }
        if self.console != other.console {
            return Some(format!(
                "console: reference {:?} vs engine {:?}",
                String::from_utf8_lossy(&self.console),
                String::from_utf8_lossy(&other.console)
            ));
        }
        if let Some(at) = first_mismatch(&self.shared, &other.shared) {
            return Some(format!(
                "shared cell byte +{}: reference {:#04x} vs engine {:#04x}",
                at, self.shared[at], other.shared[at]
            ));
        }
        if let Some(at) = first_mismatch(&self.scratch, &other.scratch) {
            return Some(format!(
                "scratch byte +{}: reference {:#04x} vs engine {:#04x}",
                at, self.scratch[at], other.scratch[at]
            ));
        }
        None
    }
}

fn first_mismatch(a: &[u8], b: &[u8]) -> Option<usize> {
    a.iter().zip(b.iter()).position(|(x, y)| x != y)
}

/// Architectural (functional) comparison of two harts.
fn diff_hart(a: &Hart, b: &Hart, compare_instret: bool) -> Option<String> {
    for r in 0..32 {
        if a.regs[r] != b.regs[r] {
            return Some(format!(
                "{} = {:#x} (reference) vs {:#x} (engine)",
                REG_NAMES[r], a.regs[r], b.regs[r]
            ));
        }
    }
    if a.pc != b.pc {
        return Some(format!("pc = {:#x} (reference) vs {:#x} (engine)", a.pc, b.pc));
    }
    if a.prv != b.prv {
        return Some(format!("privilege {:?} vs {:?}", a.prv, b.prv));
    }
    if compare_instret && a.instret != b.instret {
        return Some(format!("instret {} vs {}", a.instret, b.instret));
    }
    let csrs = [
        ("mstatus", a.mstatus, b.mstatus),
        ("mtvec", a.mtvec, b.mtvec),
        ("mscratch", a.mscratch, b.mscratch),
        ("sscratch", a.sscratch, b.sscratch),
        ("mepc", a.mepc, b.mepc),
        ("mcause", a.mcause, b.mcause),
        ("mtval", a.mtval, b.mtval),
        ("satp", a.satp, b.satp),
    ];
    for (name, x, y) in csrs {
        if x != y {
            return Some(format!("{} = {:#x} (reference) vs {:#x} (engine)", name, x, y));
        }
    }
    None
}

/// Disassemble the instruction at `pc` (flat physical addressing).
fn disasm_at(phys: &PhysMem, pc: u64) -> String {
    if !phys.contains(pc, 2) {
        return format!("{:#x}: <outside DRAM>", pc);
    }
    let lo = phys.read_u16(pc);
    let raw = if crate::isa::inst_len(lo) == 4 && phys.contains(pc + 2, 2) {
        (lo as u32) | ((phys.read_u16(pc + 2) as u32) << 16)
    } else {
        lo as u32
    };
    let (op, _) = crate::isa::decode(raw);
    format!("{:#x}: {}", pc, op)
}

// ---------------------------------------------------------------------------
// Engine construction helpers
// ---------------------------------------------------------------------------

fn sim_config(harts: usize, mode: EngineMode, pipeline: &str, memory: &str) -> SimConfig {
    SimConfig {
        harts,
        mode,
        pipeline: pipeline.into(),
        memory: memory.into(),
        ..SimConfig::default()
    }
}

fn fresh_refsim(image: &crate::asm::Image, harts: usize, memory: &str) -> RefSim {
    let cfg = sim_config(harts, EngineMode::Lockstep, "inorder", memory);
    let mut rsim = RefSim::new(build_system(&cfg));
    rsim.load(image);
    rsim
}

fn fresh_interp(image: &crate::asm::Image, harts: usize, memory: &str) -> InterpEngine {
    let cfg = sim_config(harts, EngineMode::Interp, "atomic", memory);
    let mut eng = InterpEngine::new(build_system(&cfg));
    let entry = load_flat(&eng.sys, image);
    for h in &mut eng.harts {
        h.pc = entry;
    }
    eng
}

fn fresh_fiber(
    image: &crate::asm::Image,
    harts: usize,
    pipeline: &str,
    memory: &str,
) -> FiberEngine {
    let cfg = sim_config(harts, EngineMode::Lockstep, pipeline, memory);
    let mut eng = FiberEngine::new(build_system(&cfg), pipeline);
    let entry = load_flat(&eng.sys, image);
    eng.set_entry(entry);
    eng
}

// ---------------------------------------------------------------------------
// The differential check
// ---------------------------------------------------------------------------

/// Run one generated program through every engine and the reference.
pub fn check_program(
    prog: &TestProgram,
    cfg: &DiffConfig,
    bug: BugInjection,
) -> Result<(), Divergence> {
    let clean = prog.assemble(BugInjection::None);
    let dut = prog.assemble(bug);

    // Reference run (always on the clean image — under injection the
    // engines run the sabotaged one, modelling a decode/translate bug).
    let mut rsim = fresh_refsim(&clean.image, cfg.harts, &cfg.memory);
    let re = rsim.run(cfg.max_insts);
    let ref_exit = match re {
        ExitReason::Exited(code) => code,
        other => {
            return Err(div(
                prog.seed,
                "refsim",
                format!("reference did not exit cleanly: {:?} (generator bug?)", other),
            ));
        }
    };
    let ref_state = State::from_refsim(&rsim, &clean);

    for mode in [EngineMode::Interp, EngineMode::Lockstep, EngineMode::Parallel] {
        let label = mode.as_str();
        let memory = if mode == EngineMode::Parallel { "atomic" } else { cfg.memory.as_str() };
        let pipeline = if mode == EngineMode::Lockstep { cfg.pipeline.as_str() } else { "atomic" };
        let mut ec = sim_config(cfg.harts, mode, pipeline, memory);
        ec.backend = cfg.backend;
        let mut eng = crate::coordinator::build_engine(&ec, &dut.image);
        match eng.run(cfg.max_insts) {
            ExitReason::Exited(code) if code == ref_exit => {}
            ExitReason::Exited(code) => {
                return Err(div(
                    prog.seed,
                    label,
                    format!("exit code {} != reference {}", code, ref_exit),
                ));
            }
            other => {
                return Err(div(
                    prog.seed,
                    label,
                    format!("did not exit: {:?} (reference exited {})", other, ref_exit),
                ));
            }
        }
        let snap = eng.suspend();
        let state = State::from_snapshot(&snap, &dut);
        if let Some(msg) = ref_state.diff(&state, cfg.harts == 1) {
            return Err(div(prog.seed, label, msg));
        }
        // The reference models the in-order pipeline; cross-checking its
        // cycle count only makes sense when the DBT runs the same model.
        // Dynamic-tier pipelines get their own band below.
        if mode == EngineMode::Lockstep
            && cfg.harts == 1
            && cfg.check_cycles
            && cfg.memory == "atomic"
            && cfg.pipeline == "inorder"
        {
            let dbt = state.harts[0].cycle;
            let rc = ref_state.harts[0].cycle;
            let tol = (cfg.cycle_rel_tol * rc as f64) as u64 + cfg.cycle_abs_tol;
            let delta = dbt.abs_diff(rc);
            if delta > tol {
                return Err(div(
                    prog.seed,
                    "lockstep(cycles)",
                    format!(
                        "DBT {} vs reference {} cycles (|delta| = {} > tolerance {})",
                        dbt, rc, delta, tol
                    ),
                ));
            }
        }
    }

    // Sharded engine (DESIGN.md §10): the serialized quantum-1
    // configuration must reproduce the reference exactly like lockstep
    // does (it *is* the lockstep schedule); the threaded quantum-64
    // configuration must still reach the same architectural end state —
    // its cycle counts may skew within the quantum bound, which the
    // multi-hart `diff` already tolerates by not comparing instret, and
    // the explicit band below checks for the single-hart case.
    let shard_counts: &[usize] = if cfg.harts == 1 {
        &[1]
    } else if cfg.harts >= 4 {
        // Wider topologies (4- and 8-hart sweeps) also exercise a deeper
        // shard split, so cross-shard mailbox traffic covers more than one
        // remote shard per hart.
        &[2, 4]
    } else {
        &[2]
    };
    for &shards in shard_counts {
        for &quantum in &[1u64, 64] {
            let mut ec = sim_config(
                cfg.harts,
                EngineMode::Sharded,
                cfg.pipeline.as_str(),
                cfg.memory.as_str(),
            );
            ec.shards = shards;
            ec.quantum = quantum;
            ec.backend = cfg.backend;
            let label = format!("sharded[s{},q{}]", shards, quantum);
            let mut eng = crate::coordinator::build_engine(&ec, &dut.image);
            match eng.run(cfg.max_insts) {
                ExitReason::Exited(code) if code == ref_exit => {}
                ExitReason::Exited(code) => {
                    return Err(div(
                        prog.seed,
                        &label,
                        format!("exit code {} != reference {}", code, ref_exit),
                    ));
                }
                other => {
                    return Err(div(
                        prog.seed,
                        &label,
                        format!("did not exit: {:?} (reference exited {})", other, ref_exit),
                    ));
                }
            }
            let snap = eng.suspend();
            let state = State::from_snapshot(&snap, &dut);
            // Multi-hart instret is schedule-dependent between *any*
            // engine and the reference (spin loops), so it is only pinned
            // for single-hart runs here; sharded-vs-lockstep bit-exactness
            // at quantum 1 (including instret and cycles) is enforced by
            // the dedicated equivalence suite.
            if let Some(msg) = ref_state.diff(&state, cfg.harts == 1) {
                return Err(div(prog.seed, &label, msg));
            }
            if quantum > 1
                && cfg.harts == 1
                && cfg.check_cycles
                && cfg.memory == "atomic"
                && cfg.pipeline == "inorder"
            {
                // Single hart: threaded sharding may not drift beyond the
                // DBT tolerance band either.
                let got = state.harts[0].cycle;
                let rc = ref_state.harts[0].cycle;
                let tol = (cfg.cycle_rel_tol * rc as f64) as u64 + cfg.cycle_abs_tol;
                if got.abs_diff(rc) > tol {
                    return Err(div(
                        prog.seed,
                        &format!("{}(cycles)", label),
                        format!("sharded {} vs reference {} cycles (tolerance {})", got, rc, tol),
                    ));
                }
            }
        }
    }

    // Dynamic-tier pipelines (o3) have no cycle-level reference to compare
    // against, so they get their own band: CPI plausibility plus rerun
    // determinism (the retire hook is a pure function of the retired
    // stream, DESIGN.md §14). The architectural comparison above already
    // ran with `cfg.pipeline` and must have been exact.
    let dynamic_pipeline = crate::pipeline::by_name(&cfg.pipeline)
        .map_or(false, |m| m.tier() == crate::pipeline::Tier::Dynamic);
    if dynamic_pipeline && cfg.check_cycles {
        dynamic_band_check(prog.seed, &dut, cfg, ref_exit)?;
    }

    if cfg.lockstep && cfg.harts == 1 {
        step_check(prog.seed, &dut.image, cfg)?;
        block_check(prog.seed, &dut.image, cfg)?;
    }
    Ok(())
}

/// Dynamic-tier timing band. Three runs of each configuration — lockstep,
/// and on multi-hart topologies the serialized 2-shard sharded engine —
/// must produce bit-identical per-hart `(cycle, instret)` vectors, and the
/// lead hart's CPI must fall inside a generous plausibility window (an
/// out-of-order core on straight-line integer code cannot plausibly
/// sustain CPI below 0.2 with a 4-wide retire, nor above 10 without a
/// timing-accounting bug). The sharded leg runs quantum 1: generated
/// programs join through spin loops, which the threaded quantum>1 driver
/// is explicitly not rerun-deterministic for (DESIGN.md §10) — the
/// serialized schedule exercises the sharded dynamic-tier charge paths
/// without that race.
fn dynamic_band_check(
    seed: u64,
    dut: &Assembled,
    cfg: &DiffConfig,
    ref_exit: u64,
) -> Result<(), Divergence> {
    let mut configs: Vec<(String, SimConfig)> = Vec::new();
    let mut ec = sim_config(cfg.harts, EngineMode::Lockstep, cfg.pipeline.as_str(), &cfg.memory);
    ec.backend = cfg.backend;
    configs.push((format!("{}-lockstep", cfg.pipeline), ec));
    if cfg.harts > 1 {
        let mut ec =
            sim_config(cfg.harts, EngineMode::Sharded, cfg.pipeline.as_str(), &cfg.memory);
        ec.shards = 2;
        ec.quantum = 1;
        ec.backend = cfg.backend;
        configs.push((format!("{}-sharded[s2,q1]", cfg.pipeline), ec));
    }
    for (label, ec) in &configs {
        let mut baseline: Option<Vec<(u64, u64)>> = None;
        for rerun in 0..3 {
            let mut eng = crate::coordinator::build_engine(ec, &dut.image);
            match eng.run(cfg.max_insts) {
                ExitReason::Exited(code) if code == ref_exit => {}
                other => {
                    return Err(div(
                        seed,
                        label,
                        format!(
                            "rerun {}: stopped {:?} (reference exited {})",
                            rerun, other, ref_exit
                        ),
                    ));
                }
            }
            let snap = eng.suspend();
            let cycles: Vec<(u64, u64)> =
                snap.harts.iter().map(|h| (h.cycle, h.instret)).collect();
            match &baseline {
                None => {
                    let (cyc, ret) = cycles[0];
                    if ret > 0 {
                        let cpi = cyc as f64 / ret as f64;
                        if !(0.2..=10.0).contains(&cpi) {
                            return Err(div(
                                seed,
                                label,
                                format!(
                                    "implausible CPI {:.2} ({} cycles / {} insts)",
                                    cpi, cyc, ret
                                ),
                            ));
                        }
                    }
                    baseline = Some(cycles);
                }
                Some(base) => {
                    if *base != cycles {
                        return Err(div(
                            seed,
                            label,
                            format!(
                                "rerun {} not bit-identical: {:?} vs {:?}",
                                rerun, base, cycles
                            ),
                        ));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Per-instruction lockstep: interpreter vs reference, compared after
/// every step. Both engines count trap deliveries as steps, so they stay
/// aligned through the trap path too.
fn step_check(seed: u64, image: &crate::asm::Image, cfg: &DiffConfig) -> Result<(), Divergence> {
    let mut rsim = fresh_refsim(image, 1, "atomic");
    let mut interp = fresh_interp(image, 1, "atomic");
    let mut steps = 0u64;
    loop {
        let prev_pc = rsim.harts[0].pc;
        let rr = rsim.run(1);
        let ir = InterpEngine::run(&mut interp, 1);
        if let Some(msg) = diff_hart(&rsim.harts[0], &interp.harts[0], true) {
            return Err(div(
                seed,
                "interp(step)",
                format!("step {} (after {}): {}", steps, disasm_at(&rsim.sys.phys, prev_pc), msg),
            ));
        }
        match (rr, ir) {
            (ExitReason::Exited(a), ExitReason::Exited(b)) => {
                if a != b {
                    return Err(div(seed, "interp(step)", format!("exit {} vs {}", a, b)));
                }
                return Ok(());
            }
            (ExitReason::StepLimit, ExitReason::StepLimit) => {}
            (a, b) => {
                return Err(div(
                    seed,
                    "interp(step)",
                    format!(
                        "step {} (after {}): reference stopped {:?}, interpreter {:?}",
                        steps,
                        disasm_at(&rsim.sys.phys, prev_pc),
                        a,
                        b
                    ),
                ));
            }
        }
        steps += 1;
        if steps > cfg.max_insts {
            return Err(div(seed, "interp(step)", "no exit within the step budget".into()));
        }
    }
}

/// Per-block lockstep: the DBT engine advances one translated block at a
/// time; the interpreter is advanced by the same number of *retired*
/// instructions, and the architectural state must match at every block
/// boundary.
fn block_check(seed: u64, image: &crate::asm::Image, cfg: &DiffConfig) -> Result<(), Divergence> {
    let mut fib = fresh_fiber(image, 1, &cfg.pipeline, "atomic");
    fib.backend = cfg.backend;
    let mut interp = fresh_interp(image, 1, "atomic");
    let mut blocks = 0u64;
    let mut retired = 0u64;
    loop {
        let before = fib.harts[0].instret;
        let fr = FiberEngine::run(&mut fib, 1);
        let n = fib.harts[0].instret - before;
        retired += n;
        // Advance the interpreter by the same retired count (its own trap
        // deliveries retire nothing, so step until instret catches up).
        let target = fib.harts[0].instret;
        let mut ir = ExitReason::StepLimit;
        let mut guard = 0u64;
        while interp.harts[0].instret < target {
            ir = InterpEngine::run(&mut interp, 1);
            if matches!(ir, ExitReason::Exited(_)) {
                break;
            }
            guard += 1;
            if guard > cfg.max_insts {
                return Err(div(
                    seed,
                    "lockstep(block)",
                    format!("interpreter stalled catching up to instret {}", target),
                ));
            }
        }
        if let Some(msg) = diff_hart(&fib.harts[0], &interp.harts[0], true) {
            return Err(div(
                seed,
                "lockstep(block)",
                format!(
                    "block {} (ending {}): DBT-vs-interpreter {}",
                    blocks,
                    disasm_at(&fib.sys.phys, fib.harts[0].pc),
                    msg
                ),
            ));
        }
        match (fr, ir) {
            (ExitReason::Exited(a), ExitReason::Exited(b)) => {
                if a != b {
                    return Err(div(seed, "lockstep(block)", format!("exit {} vs {}", a, b)));
                }
                return Ok(());
            }
            (ExitReason::StepLimit, ExitReason::StepLimit) => {}
            (a, b) => {
                return Err(div(
                    seed,
                    "lockstep(block)",
                    format!("block {}: DBT stopped {:?}, interpreter {:?}", blocks, a, b),
                ));
            }
        }
        blocks += 1;
        if retired > cfg.max_insts {
            return Err(div(seed, "lockstep(block)", "no exit within the block budget".into()));
        }
    }
}

/// Generate and check one seed.
pub fn run_seed(seed: u64, cfg: &DiffConfig, bug: BugInjection) -> Result<(), Divergence> {
    let prog = generator::generate(seed, cfg.harts);
    check_program(&prog, cfg, bug)
}

// ---------------------------------------------------------------------------
// Sweeps
// ---------------------------------------------------------------------------

/// Result of a seed sweep.
pub struct SweepReport {
    pub start: u64,
    pub count: u64,
    pub harts: usize,
    pub failures: Vec<Divergence>,
}

impl SweepReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "difftest: {} seed(s) [{}..{}), {} hart(s): {} failure(s)\n",
            self.count,
            self.start,
            self.start.saturating_add(self.count),
            self.harts,
            self.failures.len()
        );
        for f in &self.failures {
            s.push_str(&format!("  {}\n", f));
        }
        s
    }

    /// One failing seed per line — the CI artifact format.
    pub fn failing_seeds(&self) -> String {
        let mut s = String::new();
        for f in &self.failures {
            s.push_str(&format!("{}\n", f.seed));
        }
        s
    }
}

/// Check `count` consecutive seeds starting at `start`.
pub fn sweep(start: u64, count: u64, cfg: &DiffConfig, bug: BugInjection) -> SweepReport {
    let mut failures = Vec::new();
    for seed in start..start.saturating_add(count) {
        if let Err(d) = run_seed(seed, cfg, bug) {
            failures.push(d);
        }
    }
    SweepReport { start, count, harts: cfg.harts, failures }
}

// ---------------------------------------------------------------------------
// Shrinking
// ---------------------------------------------------------------------------

/// A minimized failing case.
pub struct Shrunk {
    pub program: TestProgram,
    pub divergence: Divergence,
    pub body_insts: usize,
}

impl Shrunk {
    pub fn report(&self) -> String {
        format!(
            "minimal repro — {}\n{}\nreproduce with: r2vm-repro difftest --seed {} --harts {}\n",
            self.divergence,
            self.program.listing(),
            self.program.seed,
            self.program.harts
        )
    }
}

fn remove_block(prog: &TestProgram, k: usize) -> TestProgram {
    let mut p = prog.clone();
    p.blocks.remove(k);
    for b in &mut p.blocks {
        if let Term::Skip { target, .. } = &mut b.term {
            if *target > k {
                *target -= 1;
            }
        }
    }
    p
}

/// Shrink a failing seed to a minimal body. Returns `None` if the seed
/// does not actually fail under `cfg`/`bug`.
pub fn shrink_seed(seed: u64, cfg: &DiffConfig, bug: BugInjection) -> Option<Shrunk> {
    let prog = generator::generate(seed, cfg.harts);
    match check_program(&prog, cfg, bug) {
        Ok(()) => None,
        Err(first) => Some(shrink_program(prog, first, cfg, bug)),
    }
}

/// Greedy fixpoint reduction: drop whole blocks, then single items, then
/// simplify terminators/padding, then drop register seeds — keeping every
/// removal that still diverges — until a pass changes nothing.
pub fn shrink_program(
    mut prog: TestProgram,
    mut last: Divergence,
    cfg: &DiffConfig,
    bug: BugInjection,
) -> Shrunk {
    loop {
        let mut changed = false;

        // Whole blocks (keep at least one so the program stays non-trivial).
        let mut i = prog.blocks.len();
        while i > 0 {
            i -= 1;
            if prog.blocks.len() <= 1 || i >= prog.blocks.len() {
                continue;
            }
            let cand = remove_block(&prog, i);
            if let Err(d) = check_program(&cand, cfg, bug) {
                prog = cand;
                last = d;
                changed = true;
            }
        }

        // Single items.
        for b in (0..prog.blocks.len()).rev() {
            let mut j = prog.blocks[b].items.len();
            while j > 0 {
                j -= 1;
                let mut cand = prog.clone();
                cand.blocks[b].items.remove(j);
                if let Err(d) = check_program(&cand, cfg, bug) {
                    prog = cand;
                    last = d;
                    changed = true;
                }
            }
        }

        // Terminator/padding simplification.
        for b in 0..prog.blocks.len() {
            if prog.blocks[b].term == Term::Next && prog.blocks[b].page_pad.is_none() {
                continue;
            }
            let mut cand = prog.clone();
            cand.blocks[b].term = Term::Next;
            cand.blocks[b].page_pad = None;
            if let Err(d) = check_program(&cand, cfg, bug) {
                prog = cand;
                last = d;
                changed = true;
            }
        }

        // Register seeds.
        for k in (0..prog.reg_seed.len()).rev() {
            let mut cand = prog.clone();
            cand.reg_seed.remove(k);
            if let Err(d) = check_program(&cand, cfg, bug) {
                prog = cand;
                last = d;
                changed = true;
            }
        }

        if !changed {
            break;
        }
    }
    Shrunk { body_insts: prog.body_insts(), program: prog, divergence: last }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_hart_smoke_seed() {
        let cfg = DiffConfig::new(1);
        run_seed(1, &cfg, BugInjection::None).unwrap();
    }

    #[test]
    fn dual_hart_smoke_seed() {
        let cfg = DiffConfig::new(2);
        run_seed(1, &cfg, BugInjection::None).unwrap();
    }

    #[test]
    fn native_backend_smoke_seed() {
        // The native x86-64 backend must be bit-identical to the micro-op
        // interpreter on the same seed; skipped where unavailable.
        if !crate::dbt::native_available() {
            return;
        }
        let mut cfg = DiffConfig::new(1);
        cfg.backend = crate::dbt::Backend::Native;
        run_seed(1, &cfg, BugInjection::None).unwrap();
    }

    #[test]
    fn o3_single_hart_smoke_seed() {
        // Dynamic-tier pipeline: architectural end state must still be
        // exact vs the reference, and the o3 band (CPI plausibility +
        // 3x-rerun bit-identical cycles) must hold.
        let mut cfg = DiffConfig::new(1);
        cfg.pipeline = "o3".into();
        run_seed(1, &cfg, BugInjection::None).unwrap();
    }

    #[test]
    fn o3_dual_hart_smoke_seed() {
        // Multi-hart o3 also covers the serialized 2-shard sharded engine
        // in the dynamic band (rerun determinism of the sharded driver's
        // dynamic-tier charge paths at quantum 1).
        let mut cfg = DiffConfig::new(2);
        cfg.pipeline = "o3".into();
        cfg.check_cycles = true;
        run_seed(1, &cfg, BugInjection::None).unwrap();
    }

    #[test]
    fn eight_hart_smoke_seed() {
        // The widest generated topology: 8 harts across 2- and 4-shard
        // sharded splits (plus the serial engines) on one seed.
        let cfg = DiffConfig::new(8);
        run_seed(3, &cfg, BugInjection::None).unwrap();
    }

    #[test]
    fn sweep_reports_format() {
        let report = SweepReport {
            start: 0,
            count: 3,
            harts: 1,
            failures: vec![div(2, "interp", "pc mismatch".into())],
        };
        assert!(!report.passed());
        assert!(report.summary().contains("1 failure"));
        assert_eq!(report.failing_seeds(), "2\n");
    }

    #[test]
    fn diff_hart_reports_first_register() {
        let a = Hart::new(0);
        let mut b = Hart::new(0);
        b.regs[10] = 7;
        let msg = diff_hart(&a, &b, true).unwrap();
        assert!(msg.contains("a0"), "{}", msg);
        b.regs[10] = 0;
        b.instret = 3;
        assert!(diff_hart(&a, &b, false).is_none(), "instret ignored when asked");
        assert!(diff_hart(&a, &b, true).is_some());
    }
}
