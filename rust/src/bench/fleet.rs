//! Fleet report aggregation: per-instance records from a COW fan-out run
//! (`coordinator::fleet`) reduced to fleet-wide percentiles and written as
//! the schema-stable `BENCH_fleet.json` (`r2vm-fleet-v1`, DESIGN.md §13).

/// Measured outcome of one successfully driven fleet instance.
#[derive(Debug, Clone)]
pub struct InstanceStats {
    /// Debug-formatted `ExitReason` of the instance's run.
    pub exit: String,
    /// Instructions this instance retired beyond the checkpoint.
    pub insts: u64,
    /// Cycles this instance accumulated beyond the checkpoint (summed
    /// over harts; 0 under non-cycle-tracking configurations).
    pub cycles: u64,
    /// Wall time of the drive loop alone.
    pub wall_secs: f64,
    /// COW restore + code-seed install time (checkpoint to runnable
    /// engine) — the number the fan-out exists to shrink.
    pub restore_secs: f64,
    /// Checkpoint content pages this instance mapped copy-on-write.
    pub pages_mapped: u64,
    /// Pages it actually cloned on first write (sharing evidence:
    /// cloned ≪ mapped).
    pub pages_cloned: u64,
    /// Blocks materialised from the shared code seed instead of being
    /// retranslated.
    pub seed_hits: u64,
    /// Blocks this instance translated itself.
    pub translations: u64,
}

impl InstanceStats {
    /// Cycles per instruction over the post-checkpoint region.
    pub fn cpi(&self) -> f64 {
        if self.insts == 0 {
            0.0
        } else {
            self.cycles as f64 / self.insts as f64
        }
    }

    /// Post-checkpoint simulation rate; 0 when unmeasurable — never
    /// inf/NaN (mirrors `RunReport::mips`).
    pub fn mips(&self) -> f64 {
        if self.wall_secs <= 0.0 || self.insts == 0 {
            0.0
        } else {
            self.insts as f64 / self.wall_secs / 1e6
        }
    }
}

/// One fleet instance: its sweep parameters and its outcome. A failed
/// instance (invalid sweep combination) is recorded, never a process
/// abort — one bad cell must not sink a thousand-instance run.
#[derive(Debug, Clone)]
pub struct InstanceResult {
    pub index: usize,
    /// Sweep parameters applied on top of the base config (`key=value`).
    pub params: Vec<(String, String)>,
    pub outcome: Result<InstanceStats, String>,
}

/// One `[lo, hi)` bucket of the MIPS histogram (the top bucket is
/// closed so the maximum lands inside it).
#[derive(Debug, Clone, Copy)]
pub struct HistBucket {
    pub lo: f64,
    pub hi: f64,
    pub count: usize,
}

/// Nearest-rank percentile over an unsorted sample (`p` in 0..=100);
/// 0.0 for an empty sample. Inputs must not contain NaN.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("percentile input must not be NaN"));
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Fixed-width linear histogram over `xs`. A degenerate sample (all
/// values equal, or empty) collapses to at most one bucket.
pub fn histogram(xs: &[f64], buckets: usize) -> Vec<HistBucket> {
    if xs.is_empty() || buckets == 0 {
        return Vec::new();
    }
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if max <= min {
        return vec![HistBucket { lo: min, hi: max, count: xs.len() }];
    }
    let width = (max - min) / buckets as f64;
    let mut out: Vec<HistBucket> = (0..buckets)
        .map(|i| HistBucket {
            lo: min + width * i as f64,
            hi: min + width * (i + 1) as f64,
            count: 0,
        })
        .collect();
    for &x in xs {
        let i = (((x - min) / width) as usize).min(buckets - 1);
        out[i].count += 1;
    }
    out
}

/// Escape a string for embedding in a JSON literal (the report embeds
/// user-supplied sweep values and error messages).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Number of MIPS histogram buckets in the JSON report.
pub const MIPS_BUCKETS: usize = 10;

/// The aggregated result of one fleet run.
pub struct FleetReport {
    /// Instances requested (= `results.len()`).
    pub instances: usize,
    /// Host worker threads the instances were multiplexed onto.
    pub workers: usize,
    /// Wall time of the whole fan-out, warm-up included.
    pub wall_secs: f64,
    /// Content pages in the shared checkpoint page set (per-instance
    /// `pages_mapped` counts this same set).
    pub shared_pages: u64,
    /// Blocks the warm-up instance translated to build the code seed
    /// (0 when code sharing was off or the warm-up found nothing).
    pub warmup_translations: u64,
    /// Distinct blocks in the shared seed.
    pub seed_blocks: u64,
    pub results: Vec<InstanceResult>,
}

impl FleetReport {
    /// Successfully driven instances.
    pub fn ok(&self) -> Vec<&InstanceStats> {
        self.results.iter().filter_map(|r| r.outcome.as_ref().ok()).collect()
    }

    /// Instances that failed to configure or validate.
    pub fn failed(&self) -> usize {
        self.results.iter().filter(|r| r.outcome.is_err()).count()
    }

    /// CPI sample: instances that retired work under a cycle-tracking
    /// configuration (atomic-pipeline instances report 0 cycles and
    /// would poison the percentiles).
    pub fn cpis(&self) -> Vec<f64> {
        self.ok().iter().filter(|s| s.insts > 0 && s.cycles > 0).map(|s| s.cpi()).collect()
    }

    /// Post-checkpoint MIPS sample over the successful instances.
    pub fn mipses(&self) -> Vec<f64> {
        self.ok().iter().map(|s| s.mips()).collect()
    }

    /// Restore-time sample in milliseconds over the successful instances.
    pub fn restores_ms(&self) -> Vec<f64> {
        self.ok().iter().map(|s| s.restore_secs * 1e3).collect()
    }

    pub fn pages_mapped_total(&self) -> u64 {
        self.ok().iter().map(|s| s.pages_mapped).sum()
    }

    pub fn pages_cloned_total(&self) -> u64 {
        self.ok().iter().map(|s| s.pages_cloned).sum()
    }

    pub fn seed_hits_total(&self) -> u64 {
        self.ok().iter().map(|s| s.seed_hits).sum()
    }

    pub fn translations_total(&self) -> u64 {
        self.ok().iter().map(|s| s.translations).sum()
    }

    /// Machine-readable report (schema `r2vm-fleet-v1`).
    pub fn to_json(&self) -> String {
        let cpis = self.cpis();
        let mipses = self.mipses();
        let restores = self.restores_ms();
        let mips_min = mipses.iter().cloned().fold(f64::INFINITY, f64::min);
        let mips_max = mipses.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"r2vm-fleet-v1\",\n");
        s.push_str(&format!("  \"instances\": {},\n", self.instances));
        s.push_str(&format!("  \"workers\": {},\n", self.workers));
        s.push_str(&format!("  \"failed\": {},\n", self.failed()));
        s.push_str(&format!("  \"wall_seconds\": {:.6},\n", self.wall_secs));
        s.push_str(&format!(
            "  \"restore_ms\": {{\"p50\": {:.6}, \"p99\": {:.6}, \"max\": {:.6}}},\n",
            percentile(&restores, 50.0),
            percentile(&restores, 99.0),
            percentile(&restores, 100.0)
        ));
        s.push_str(&format!(
            "  \"cpi\": {{\"p50\": {:.6}, \"p99\": {:.6}}},\n",
            percentile(&cpis, 50.0),
            percentile(&cpis, 99.0)
        ));
        s.push_str(&format!(
            "  \"mips\": {{\"min\": {:.6}, \"p50\": {:.6}, \"max\": {:.6}}},\n",
            if mips_min.is_finite() { mips_min } else { 0.0 },
            percentile(&mipses, 50.0),
            if mips_max.is_finite() { mips_max } else { 0.0 }
        ));
        s.push_str("  \"mips_histogram\": [");
        for (i, b) in histogram(&mipses, MIPS_BUCKETS).iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"lo\": {:.6}, \"hi\": {:.6}, \"count\": {}}}",
                b.lo, b.hi, b.count
            ));
        }
        s.push_str("],\n");
        s.push_str(&format!(
            "  \"cow\": {{\"shared_pages\": {}, \"pages_mapped_total\": {}, \
             \"pages_cloned_total\": {}}},\n",
            self.shared_pages,
            self.pages_mapped_total(),
            self.pages_cloned_total()
        ));
        s.push_str(&format!(
            "  \"code_seed\": {{\"warmup_translations\": {}, \"seed_blocks\": {}, \
             \"seed_hits_total\": {}, \"translations_total\": {}}},\n",
            self.warmup_translations,
            self.seed_blocks,
            self.seed_hits_total(),
            self.translations_total()
        ));
        s.push_str("  \"cells\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            s.push_str(&format!("    {{\"index\": {}, \"params\": {{", r.index));
            for (j, (k, v)) in r.params.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)));
            }
            s.push_str("}, ");
            match &r.outcome {
                Ok(st) => s.push_str(&format!(
                    "\"ok\": true, \"exit\": \"{}\", \"insts\": {}, \"cycles\": {}, \
                     \"cpi\": {:.6}, \"mips\": {:.6}, \"wall_secs\": {:.6}, \
                     \"restore_secs\": {:.6}, \"pages_mapped\": {}, \"pages_cloned\": {}, \
                     \"seed_hits\": {}, \"translations\": {}}}",
                    json_escape(&st.exit),
                    st.insts,
                    st.cycles,
                    st.cpi(),
                    st.mips(),
                    st.wall_secs,
                    st.restore_secs,
                    st.pages_mapped,
                    st.pages_cloned,
                    st.seed_hits,
                    st.translations
                )),
                Err(e) => {
                    s.push_str(&format!("\"ok\": false, \"error\": \"{}\"}}", json_escape(e)))
                }
            }
            if i + 1 < self.results.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    /// Human-readable fleet summary.
    pub fn table(&self) -> String {
        let cpis = self.cpis();
        let mipses = self.mipses();
        let restores = self.restores_ms();
        let mut s = format!(
            "=== fleet: {} instances on {} workers in {:.3}s ({} failed) ===\n",
            self.instances,
            self.workers,
            self.wall_secs,
            self.failed()
        );
        s.push_str(&format!(
            "  restore: p50 {:.3}ms  p99 {:.3}ms  max {:.3}ms\n",
            percentile(&restores, 50.0),
            percentile(&restores, 99.0),
            percentile(&restores, 100.0)
        ));
        if !cpis.is_empty() {
            s.push_str(&format!(
                "  cpi:     p50 {:.3}  p99 {:.3}\n",
                percentile(&cpis, 50.0),
                percentile(&cpis, 99.0)
            ));
        }
        s.push_str(&format!(
            "  mips:    min {:.1}  p50 {:.1}  max {:.1}\n",
            percentile(&mipses, 0.0),
            percentile(&mipses, 50.0),
            percentile(&mipses, 100.0)
        ));
        for b in histogram(&mipses, MIPS_BUCKETS) {
            s.push_str(&format!(
                "    [{:>8.1}, {:>8.1})  {:>5}  {}\n",
                b.lo,
                b.hi,
                b.count,
                "#".repeat(b.count.min(60))
            ));
        }
        s.push_str(&format!(
            "  cow:     {} shared pages; mapped {} / cloned {} across the fleet\n",
            self.shared_pages,
            self.pages_mapped_total(),
            self.pages_cloned_total()
        ));
        s.push_str(&format!(
            "  code:    {} warm-up translations -> {} seed blocks; \
             {} seed hits vs {} fleet translations\n",
            self.warmup_translations,
            self.seed_blocks,
            self.seed_hits_total(),
            self.translations_total()
        ));
        for r in &self.results {
            if let Err(e) = &r.outcome {
                s.push_str(&format!("  instance {} FAILED: {}\n", r.index, e));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(insts: u64, cycles: u64, wall: f64, restore: f64) -> InstanceStats {
        InstanceStats {
            exit: "Exited(0)".into(),
            insts,
            cycles,
            wall_secs: wall,
            restore_secs: restore,
            pages_mapped: 4,
            pages_cloned: 1,
            seed_hits: 10,
            translations: 2,
        }
    }

    fn demo_report() -> FleetReport {
        let results = (0..8)
            .map(|i| InstanceResult {
                index: i,
                params: vec![("pipeline".into(), "simple".into())],
                outcome: if i == 7 {
                    Err("unknown option --bogus".into())
                } else {
                    Ok(stats(1_000, 2_000 + 100 * i as u64, 0.001 * (i + 1) as f64, 0.0001))
                },
            })
            .collect();
        FleetReport {
            instances: 8,
            workers: 2,
            wall_secs: 0.5,
            shared_pages: 4,
            warmup_translations: 12,
            seed_blocks: 12,
            results,
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[5.0], 99.0), 5.0);
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 3.0, "nearest rank rounds up at .5");
    }

    #[test]
    fn histogram_covers_extremes_and_degenerates() {
        let h = histogram(&[0.0, 5.0, 10.0], 10);
        assert_eq!(h.len(), 10);
        assert_eq!(h.iter().map(|b| b.count).sum::<usize>(), 3);
        assert_eq!(h[0].count, 1);
        assert_eq!(h[9].count, 1, "the maximum lands in the closed top bucket");
        let flat = histogram(&[2.0, 2.0], 10);
        assert_eq!(flat.len(), 1, "degenerate sample collapses");
        assert_eq!(flat[0].count, 2);
        assert!(histogram(&[], 10).is_empty());
    }

    #[test]
    fn report_json_schema_is_stable() {
        let r = demo_report();
        let json = r.to_json();
        assert!(json.contains("\"schema\": \"r2vm-fleet-v1\""));
        assert!(json.contains("\"instances\": 8"));
        assert!(json.contains("\"failed\": 1"));
        assert!(json.contains("\"restore_ms\""));
        assert!(json.contains("\"cpi\": {\"p50\":"));
        assert!(json.contains("\"mips_histogram\""));
        assert!(json.contains("\"pages_cloned_total\": 7"));
        assert!(json.contains("\"seed_hits_total\": 70"));
        assert!(json.contains("\"ok\": false, \"error\": \"unknown option --bogus\""));
        // Crude structural checks (no JSON parser offline): balanced
        // braces/brackets, no trailing comma before a closing bracket.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert!(!json.contains(",\n  ]"));
        assert!(!json.contains(",}"));
    }

    #[test]
    fn json_escaping_defuses_hostile_strings() {
        let mut r = demo_report();
        r.results[7].outcome = Err("quote \" backslash \\ newline \n end".into());
        let json = r.to_json();
        assert!(json.contains("quote \\\" backslash \\\\ newline \\n end"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn table_reports_failures_and_sharing() {
        let r = demo_report();
        let t = r.table();
        assert!(t.contains("8 instances"));
        assert!(t.contains("instance 7 FAILED"));
        assert!(t.contains("shared pages"));
        assert!(t.contains("seed hits"));
    }
}
