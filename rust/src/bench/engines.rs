//! The `bench` subcommand: a reproducible benchmark baseline across
//! workload × engine × model cells, written to `BENCH_engines.json`.
//!
//! Every built-in workload is run under a fixed configuration matrix —
//! the functional-parallel engine (QEMU-comparable, Figure 5's fast bar)
//! and the lockstep DBT engine under simple/atomic and inorder with the
//! tlb/cache/mesi memory models — plus a dispatch-ablation pair on the
//! coremark workload: chain-following dispatch (the default) against
//! block-lookup-only dispatch (`--no-chaining`), so every future PR can
//! read the dispatch win straight out of the JSON trajectory.
//!
//! Methodology (DESIGN.md §9): one untimed warm-up run, then best-of-N
//! wall time via [`crate::bench::bench`], with the best run's own work
//! count paired to its time. Each timed run boots a fresh engine, so the
//! numbers include translation warm-up — deliberately: they are
//! end-to-end run MIPS, reproducible without a steady-state protocol.
//! Counter fields (insts/cycles/chain/model stats) also come from the
//! best timed run, so every field of a cell describes the same run.

use crate::bench::{bench_with, Measurement};
use crate::coordinator::{run_image, EngineMode, SimConfig};
use crate::engine::{EngineStats, ExitReason};
use crate::workloads;
use std::time::Duration;

/// Options for one `bench` invocation.
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Timed runs per cell (after one warm-up); clamped to >= 1.
    pub runs: u32,
    /// Reduced workload sizes (the CI smoke configuration).
    pub quick: bool,
    /// Restrict to one workload by name.
    pub workload: Option<String>,
    /// Where the machine-readable report is written.
    pub json_path: String,
    /// Baseline JSON to diff against (`--compare`): per-row MIPS deltas.
    pub compare_path: Option<String>,
    /// With `compare_path`: exit nonzero when any matched row's MIPS
    /// regresses more than this many percent vs the baseline.
    pub fail_threshold: Option<f64>,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            runs: 3,
            quick: false,
            workload: None,
            json_path: "BENCH_engines.json".into(),
            compare_path: None,
            fail_threshold: None,
        }
    }
}

/// (workload, harts): multi-core workloads run with two harts so the
/// coherent models have actual sharing to simulate; `multicore` runs with
/// four so the shard-scaling rows have something to spread.
pub const BENCH_WORKLOADS: &[(&str, usize)] = &[
    ("coremark-lite", 1),
    ("memlat", 1),
    ("dedup", 2),
    ("spinlock", 2),
    ("multicore", 4),
    ("vm-sv39", 1),
];

/// (mode, pipeline, memory) configuration matrix, Table 1 × Table 2's
/// valid engine/model combinations at benchmark-relevant points.
const MATRIX: &[(&str, &str, &str)] = &[
    ("parallel", "atomic", "atomic"),
    ("lockstep", "simple", "atomic"),
    ("lockstep", "inorder", "tlb"),
    ("lockstep", "inorder", "cache"),
    ("lockstep", "inorder", "mesi"),
];

/// Shard-scaling matrix (DESIGN.md §10), measured on the 4-hart
/// `multicore` workload under the cycle-level inorder+cache configuration:
/// shards × quantum. `(1, 1)` doubles as the serialized-sharding baseline
/// (bit-identical to lockstep), `(4, 1024)` is the headline parallel cell.
const SHARD_MATRIX: &[(usize, u64)] = &[
    (1, 1),
    (1, 64),
    (1, 1024),
    (2, 1),
    (2, 64),
    (2, 1024),
    (4, 1),
    (4, 64),
    (4, 1024),
];

/// One measured workload × configuration cell.
pub struct Cell {
    pub workload: String,
    pub mode: &'static str,
    pub pipeline: &'static str,
    pub memory: &'static str,
    /// "chain" (default dispatch) or "lookup" (`--no-chaining` ablation).
    pub dispatch: &'static str,
    pub harts: usize,
    /// Sharded-engine cells: (shards, quantum); `None` for every other
    /// engine (their JSON rows keep the pre-sharding schema).
    pub sharding: Option<(usize, u64)>,
    /// `true` on the adaptive-quantum twin row (`--adaptive-quantum`
    /// controller on, DESIGN.md §15); `false` everywhere else — the
    /// fixed-quantum rows keep their exact pre-adaptive schema.
    pub adaptive: bool,
    /// `Some("native")` on native-DBT-backend rows; `None` on the default
    /// micro-op rows, which keep their exact pre-native schema.
    pub backend: Option<&'static str>,
    /// `Some("traced")` on the observability-ablation row (event tracing
    /// plus block profiling enabled); `None` on every ordinary row, which
    /// keeps its exact pre-observability schema.
    pub obs: Option<&'static str>,
    pub measurement: Measurement,
    /// Guest instructions / simulated cycles of the best timed run (the
    /// run `measurement.best` measures).
    pub insts: u64,
    pub cycles: u64,
    /// Exit code if the guest exited cleanly.
    pub exit: Option<u64>,
    pub engine_stats: EngineStats,
    pub model_stats: Vec<(&'static str, u64)>,
}

/// The one label format shared by live cells and skipped-cell records.
fn cell_label(
    workload: &str,
    mode: &str,
    pipeline: &str,
    memory: &str,
    lookup_dispatch: bool,
    sharding: Option<(usize, u64)>,
    adaptive: bool,
    backend: Option<&str>,
    obs: Option<&str>,
) -> String {
    let ablation = if lookup_dispatch { "/nochain" } else { "" };
    let native = match backend {
        Some(b) => format!("/{}", b),
        None => String::new(),
    };
    let traced = match obs {
        Some(o) => format!("/{}", o),
        None => String::new(),
    };
    let shard = match sharding {
        Some((s, q)) => format!("[s{},q{}{}]", s, q, if adaptive { ",aq" } else { "" }),
        None => String::new(),
    };
    format!(
        "{} {}{}/{}+{}{}{}{}",
        workload, mode, shard, pipeline, memory, ablation, native, traced
    )
}

impl Cell {
    pub fn label(&self) -> String {
        cell_label(
            &self.workload,
            self.mode,
            self.pipeline,
            self.memory,
            self.dispatch == "lookup",
            self.sharding,
            self.adaptive,
            self.backend,
            self.obs,
        )
    }

    /// Identity key for baseline comparison — every dimension that makes a
    /// row distinct, in a fixed order shared with [`line_key`].
    pub fn key(&self) -> String {
        let shard = match self.sharding {
            Some((s, q)) => {
                format!("[s{},q{}{}]", s, q, if self.adaptive { ",aq" } else { "" })
            }
            None => String::new(),
        };
        let traced = match self.obs {
            Some(o) => format!("/{}", o),
            None => String::new(),
        };
        format!(
            "{} {}{}/{}+{}/{}/{}{}",
            self.workload,
            self.mode,
            shard,
            self.pipeline,
            self.memory,
            self.dispatch,
            self.backend.unwrap_or("microop"),
            traced
        )
    }

    pub fn mips(&self) -> f64 {
        self.measurement.mips()
    }
}

/// The full bench report.
pub struct BenchReport {
    pub quick: bool,
    pub runs: u32,
    pub cells: Vec<Cell>,
    /// Labels of matrix cells that could not run at all (workload failed
    /// to build, configuration rejected): recorded in the JSON so a
    /// vanished row reads as "failed", never as "not measured".
    pub skipped: Vec<String>,
    pub host_cpus: usize,
}

/// Run one cell: boot a fresh engine per timed run, best-of-N.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    workload: &str,
    harts: usize,
    mode: &'static str,
    pipeline: &'static str,
    memory: &'static str,
    lookup_dispatch: bool,
    sharding: Option<(usize, u64)>,
    adaptive: bool,
    backend: Option<&'static str>,
    traced: bool,
    runs: u32,
    quick: bool,
) -> Option<Cell> {
    let image = workloads::build_bench(workload, harts, quick)?;
    let mut cfg = SimConfig::default();
    cfg.harts = harts;
    cfg.mode = EngineMode::parse(mode)?;
    cfg.pipeline = pipeline.into();
    cfg.memory = memory.into();
    cfg.no_chaining = lookup_dispatch;
    if backend == Some("native") {
        cfg.backend = crate::dbt::Backend::Native;
    }
    if traced {
        // Observability ablation: event tracing + block profiling on, no
        // output file — measures the recording overhead itself.
        cfg.trace_events = true;
        cfg.profile = true;
    }
    if let Some((shards, quantum)) = sharding {
        cfg.shards = shards;
        cfg.quantum = quantum;
    }
    // Adaptive twin: epoch controller on, bounds at their documented
    // defaults — `sharding` seeds the starting quantum.
    cfg.adaptive_quantum = adaptive;
    // Backstop so a regressed workload shows up as a truncated cell
    // instead of a hung bench (generous: every built-in workload retires
    // orders of magnitude less).
    cfg.max_insts = 4_000_000_000;
    if cfg.validate().is_err() {
        return None;
    }

    let dispatch = if lookup_dispatch { "lookup" } else { "chain" };
    let mut cell = Cell {
        workload: workload.into(),
        mode,
        pipeline,
        memory,
        dispatch,
        harts,
        sharding,
        adaptive,
        backend,
        obs: traced.then_some("traced"),
        measurement: Measurement {
            name: String::new(),
            best: Duration::ZERO,
            mean: Duration::ZERO,
            work: 0,
            runs: 0,
        },
        insts: 0,
        cycles: 0,
        exit: None,
        engine_stats: EngineStats::default(),
        model_stats: Vec::new(),
    };
    // bench_with carries the best run's full report alongside the
    // measurement, so every field of the cell — work, best_secs, insts,
    // cycles, engine/model stats — describes the same run (per-run counts
    // vary in the parallel engine).
    let label = cell.label();
    let (measurement, report) = bench_with(&label, runs.max(1), || {
        let report = run_image(&cfg, &image);
        (report.total_insts, report)
    })?;
    cell.measurement = measurement;
    cell.insts = report.total_insts;
    cell.cycles = report.per_hart.iter().map(|&(c, _)| c).sum();
    cell.exit = match report.exit {
        ExitReason::Exited(code) => Some(code),
        _ => None,
    };
    cell.engine_stats = report.engine_stats.unwrap_or_default();
    cell.model_stats = report.model_stats.clone();
    Some(cell)
}

/// Run the full matrix.
pub fn run_bench(opts: &BenchOptions) -> BenchReport {
    let runs = opts.runs.max(1);
    let mut cells = Vec::new();
    let mut skipped = Vec::new();
    for &(workload, harts) in BENCH_WORKLOADS {
        if let Some(only) = &opts.workload {
            if only != workload {
                continue;
            }
        }
        for &(mode, pipeline, memory) in MATRIX {
            let mut variants = vec![false];
            // Dispatch ablation: the chain-vs-lookup pair is measured on
            // the coremark cell only (hot loops, pipeline-bound — the
            // configuration where dispatch cost is most visible).
            if workload == "coremark-lite" && mode == "lockstep" && memory == "atomic" {
                variants.push(true);
            }
            // Backend ablation: every coremark lockstep row gains a
            // native-code twin where the host supports it, so the
            // micro-op-vs-native win is readable per memory model. Gated
            // on availability up front — an unavailable backend is not a
            // failed cell.
            let mut backends: Vec<Option<&'static str>> = vec![None];
            if workload == "coremark-lite"
                && mode == "lockstep"
                && crate::dbt::native_available()
            {
                backends.push(Some("native"));
            }
            for backend in backends {
                for &lookup in &variants {
                    match run_cell(
                        workload, harts, mode, pipeline, memory, lookup, None, false, backend,
                        false, runs, opts.quick,
                    ) {
                        Some(cell) => cells.push(cell),
                        None => {
                            let label = cell_label(
                                workload, mode, pipeline, memory, lookup, None, false, backend,
                                None,
                            );
                            eprintln!("warning: bench cell {} could not run (skipped)", label);
                            skipped.push(label);
                        }
                    }
                }
            }
        }
        // Observability ablation (DESIGN.md §12): the coremark chain cell
        // re-measured with event tracing + block profiling enabled, next
        // to its untraced twin above, so the trace-on overhead — and the
        // disabled-path "within noise" contract — is readable per PR.
        if workload == "coremark-lite" {
            match run_cell(
                workload, harts, "lockstep", "simple", "atomic", false, None, false, None, true,
                runs, opts.quick,
            ) {
                Some(cell) => cells.push(cell),
                None => {
                    let label = cell_label(
                        workload,
                        "lockstep",
                        "simple",
                        "atomic",
                        false,
                        None,
                        false,
                        None,
                        Some("traced"),
                    );
                    eprintln!("warning: bench cell {} could not run (skipped)", label);
                    skipped.push(label);
                }
            }
        }
        // Dynamic-tier rows (DESIGN.md §14): the o3 model next to an
        // inorder twin under the same lockstep+atomic configuration, so
        // the static-vs-dynamic timing-tier cost is a single ratio
        // (`inorder_o3_mips_ratio`). These extend the matrix as new rows;
        // the `--fail-threshold` gate never fails on rows missing from an
        // older baseline.
        if workload == "coremark-lite" {
            for &pipeline in &["inorder", "o3"] {
                match run_cell(
                    workload, harts, "lockstep", pipeline, "atomic", false, None, false, None,
                    false, runs, opts.quick,
                ) {
                    Some(cell) => cells.push(cell),
                    None => {
                        let label = cell_label(
                            workload, "lockstep", pipeline, "atomic", false, None, false, None,
                            None,
                        );
                        eprintln!("warning: bench cell {} could not run (skipped)", label);
                        skipped.push(label);
                    }
                }
            }
        }
        // Shard-scaling rows (DESIGN.md §10): the sharded engine across
        // SHARD_MATRIX on the 4-hart multicore workload under the
        // cycle-level inorder+cache configuration.
        if workload == "multicore" {
            for &(shards, quantum) in SHARD_MATRIX {
                let sharding = Some((shards, quantum));
                match run_cell(
                    workload, harts, "sharded", "inorder", "cache", false, sharding, false, None,
                    false, runs, opts.quick,
                ) {
                    Some(cell) => cells.push(cell),
                    None => {
                        let label = cell_label(
                            workload, "sharded", "inorder", "cache", false, sharding, false, None,
                            None,
                        );
                        eprintln!("warning: bench cell {} could not run (skipped)", label);
                        skipped.push(label);
                    }
                }
            }
            // Adaptive-quantum twin (DESIGN.md §15): the headline (4, 1024)
            // sharded cell re-measured with the epoch controller on, so
            // the adaptive-vs-fixed-quantum win is a single JSON ratio
            // (`adaptive_q_speedup`).
            let sharding = Some((4, 1024));
            match run_cell(
                workload, harts, "sharded", "inorder", "cache", false, sharding, true, None,
                false, runs, opts.quick,
            ) {
                Some(cell) => cells.push(cell),
                None => {
                    let label = cell_label(
                        workload, "sharded", "inorder", "cache", false, sharding, true, None, None,
                    );
                    eprintln!("warning: bench cell {} could not run (skipped)", label);
                    skipped.push(label);
                }
            }
            // The o3 model on the 4-hart coherent configuration: the
            // dynamic tier must also hold up under multicore MESI timing.
            match run_cell(
                workload, harts, "lockstep", "o3", "mesi", false, None, false, None, false, runs,
                opts.quick,
            ) {
                Some(cell) => cells.push(cell),
                None => {
                    let label = cell_label(
                        workload, "lockstep", "o3", "mesi", false, None, false, None, None,
                    );
                    eprintln!("warning: bench cell {} could not run (skipped)", label);
                    skipped.push(label);
                }
            }
        }
    }
    BenchReport {
        quick: opts.quick,
        runs,
        cells,
        skipped,
        host_cpus: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

// ---------------------------------------------------------------------------
// Baseline comparison (`bench --compare`)
// ---------------------------------------------------------------------------
//
// The report's own JSON is line-oriented — one cell object per line — so a
// committed baseline can be diffed without a JSON parser (none offline):
// each cell line is keyed by its identity fields and its "mips" value.

/// Raw text of `"key": <value>` in a single-line JSON object, exclusive of
/// the trailing comma/brace.
fn json_field_raw<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{}\": ", key);
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    Some(rest[..end].trim())
}

fn json_str_field(line: &str, key: &str) -> Option<String> {
    let raw = json_field_raw(line, key)?;
    Some(raw.strip_prefix('"')?.strip_suffix('"')?.to_string())
}

fn json_num_field(line: &str, key: &str) -> Option<f64> {
    json_field_raw(line, key)?.parse().ok()
}

/// Identity key of one baseline cell line — same format as [`Cell::key`].
/// Baselines predating the backend dimension read as "microop".
fn line_key(line: &str) -> Option<String> {
    let workload = json_str_field(line, "workload")?;
    let mode = json_str_field(line, "mode")?;
    let pipeline = json_str_field(line, "pipeline")?;
    let memory = json_str_field(line, "memory")?;
    let dispatch = json_str_field(line, "dispatch")?;
    let backend = json_str_field(line, "backend").unwrap_or_else(|| "microop".into());
    let traced = json_str_field(line, "obs").map(|o| format!("/{}", o)).unwrap_or_default();
    let adaptive = json_field_raw(line, "adaptive_quantum") == Some("true");
    let shard = match (json_num_field(line, "shards"), json_num_field(line, "quantum")) {
        (Some(s), Some(q)) => {
            format!("[s{},q{}{}]", s as u64, q as u64, if adaptive { ",aq" } else { "" })
        }
        _ => String::new(),
    };
    Some(format!(
        "{} {}{}/{}+{}/{}/{}{}",
        workload, mode, shard, pipeline, memory, dispatch, backend, traced
    ))
}

/// Extract `(identity key, mips)` per cell row of a baseline report JSON.
pub fn parse_baseline_cells(json: &str) -> Vec<(String, f64)> {
    json.lines()
        .filter(|l| l.trim_start().starts_with("{\"workload\""))
        .filter_map(|l| Some((line_key(l)?, json_num_field(l, "mips")?)))
        .collect()
}

impl BenchReport {
    fn coremark_mips(&self, dispatch: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| {
                c.workload == "coremark-lite"
                    && c.mode == "lockstep"
                    && c.memory == "atomic"
                    && c.dispatch == dispatch
                    && c.backend.is_none()
                    && c.obs.is_none()
            })
            .map(Cell::mips)
    }

    /// Traced twin of the coremark chain cell (tracing + profiling on).
    pub fn coremark_traced_mips(&self) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| {
                c.workload == "coremark-lite"
                    && c.mode == "lockstep"
                    && c.memory == "atomic"
                    && c.dispatch == "chain"
                    && c.backend.is_none()
                    && c.obs == Some("traced")
            })
            .map(Cell::mips)
    }

    /// Native-backend chain-dispatch MIPS on the coremark atomic cell
    /// (`None` where the native backend is unavailable).
    pub fn coremark_native_mips(&self) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| {
                c.workload == "coremark-lite"
                    && c.mode == "lockstep"
                    && c.memory == "atomic"
                    && c.dispatch == "chain"
                    && c.backend == Some("native")
                    && c.obs.is_none()
            })
            .map(Cell::mips)
    }

    /// MIPS of the plain (chain, micro-op, untraced) lockstep coremark
    /// cell running `pipeline` under the atomic memory model.
    fn coremark_pipeline_mips(&self, pipeline: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| {
                c.workload == "coremark-lite"
                    && c.mode == "lockstep"
                    && c.pipeline == pipeline
                    && c.memory == "atomic"
                    && c.dispatch == "chain"
                    && c.backend.is_none()
                    && c.obs.is_none()
            })
            .map(Cell::mips)
    }

    /// Dynamic-tier (o3) coremark MIPS.
    pub fn coremark_o3_mips(&self) -> Option<f64> {
        self.coremark_pipeline_mips("o3")
    }

    /// Static-vs-dynamic tier cost: inorder MIPS over o3 MIPS on the same
    /// lockstep+atomic coremark cell (how much the runtime retire hook
    /// costs relative to translation-time baked cycle counts).
    pub fn inorder_o3_mips_ratio(&self) -> Option<f64> {
        match (self.coremark_pipeline_mips("inorder"), self.coremark_o3_mips()) {
            (Some(i), Some(o)) if o > 0.0 => Some(i / o),
            _ => None,
        }
    }

    /// Chain-following dispatch MIPS on the coremark cell.
    pub fn coremark_chain_mips(&self) -> Option<f64> {
        self.coremark_mips("chain")
    }

    /// Block-lookup-only dispatch MIPS on the coremark cell.
    pub fn coremark_lookup_mips(&self) -> Option<f64> {
        self.coremark_mips("lookup")
    }

    /// MIPS of the fixed-quantum sharded multicore cell at
    /// `(shards, quantum)` (the adaptive twin is excluded — it shares the
    /// seed configuration but measures the controller).
    pub fn shard_mips(&self, shards: usize, quantum: u64) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| {
                c.workload == "multicore" && c.sharding == Some((shards, quantum)) && !c.adaptive
            })
            .map(Cell::mips)
    }

    /// The headline shard-scaling ratio: S=4 over S=1 at quantum 1024.
    pub fn shard_speedup_q1024(&self) -> Option<f64> {
        match (self.shard_mips(1, 1024), self.shard_mips(4, 1024)) {
            (Some(s1), Some(s4)) if s1 > 0.0 => Some(s4 / s1),
            _ => None,
        }
    }

    /// MIPS of the adaptive-quantum multicore twin (epoch controller on,
    /// seeded at the headline S=4, q=1024 configuration).
    pub fn adaptive_q_mips(&self) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.workload == "multicore" && c.adaptive)
            .map(Cell::mips)
    }

    /// Adaptive-vs-fixed-quantum ratio on the headline S=4 sharded cell.
    pub fn adaptive_q_speedup(&self) -> Option<f64> {
        match (self.shard_mips(4, 1024), self.adaptive_q_mips()) {
            (Some(fixed), Some(adaptive)) if fixed > 0.0 => Some(adaptive / fixed),
            _ => None,
        }
    }

    /// Human-readable table.
    pub fn table(&self) -> String {
        let mut s = format!(
            "bench: {} cell(s), best of {} run(s){}, {} host cpu(s)\n",
            self.cells.len(),
            self.runs,
            if self.quick { " [quick sizes]" } else { "" },
            self.host_cpus
        );
        for cell in &self.cells {
            let stats = &cell.engine_stats;
            s.push_str(&format!(
                "{:<44} {:>9.2} MIPS  best {:>8.3}s  insts {:>12}  chain {:.1}%{}\n",
                cell.label(),
                cell.mips(),
                cell.measurement.best.as_secs_f64(),
                cell.insts,
                100.0 * stats.chain_hit_rate(),
                if cell.exit.is_some() { "" } else { "  [NO CLEAN EXIT]" },
            ));
        }
        for label in &self.skipped {
            s.push_str(&format!("{:<44}    [SKIPPED — could not run]\n", label));
        }
        if let (Some(chain), Some(lookup)) = (self.coremark_chain_mips(), self.coremark_lookup_mips())
        {
            if lookup > 0.0 {
                s.push_str(&format!(
                    "coremark dispatch: chain {:.2} MIPS vs lookup {:.2} MIPS ({:.2}x)\n",
                    chain,
                    lookup,
                    chain / lookup
                ));
            }
        }
        if let (Some(s1), Some(s4), Some(ratio)) =
            (self.shard_mips(1, 1024), self.shard_mips(4, 1024), self.shard_speedup_q1024())
        {
            s.push_str(&format!(
                "multicore shard scaling @q1024: s1 {:.2} MIPS vs s4 {:.2} MIPS ({:.2}x)\n",
                s1, s4, ratio
            ));
        }
        if let (Some(fixed), Some(adaptive), Some(ratio)) =
            (self.shard_mips(4, 1024), self.adaptive_q_mips(), self.adaptive_q_speedup())
        {
            s.push_str(&format!(
                "multicore adaptive quantum @s4: fixed {:.2} MIPS vs adaptive {:.2} MIPS ({:.2}x)\n",
                fixed, adaptive, ratio
            ));
        }
        if let (Some(micro), Some(native)) =
            (self.coremark_chain_mips(), self.coremark_native_mips())
        {
            if micro > 0.0 {
                s.push_str(&format!(
                    "coremark backend: microop {:.2} MIPS vs native {:.2} MIPS ({:.2}x)\n",
                    micro,
                    native,
                    native / micro
                ));
            }
        }
        if let (Some(off), Some(on)) = (self.coremark_chain_mips(), self.coremark_traced_mips()) {
            if on > 0.0 {
                s.push_str(&format!(
                    "coremark tracing: off {:.2} MIPS vs on {:.2} MIPS ({:.2}x)\n",
                    off,
                    on,
                    off / on
                ));
            }
        }
        if let (Some(i), Some(o), Some(ratio)) = (
            self.coremark_pipeline_mips("inorder"),
            self.coremark_o3_mips(),
            self.inorder_o3_mips_ratio(),
        ) {
            s.push_str(&format!(
                "coremark timing tier: inorder {:.2} MIPS vs o3 {:.2} MIPS ({:.2}x)\n",
                i, o, ratio
            ));
        }
        s
    }

    /// Per-row MIPS deltas against a baseline report's JSON (the
    /// `--compare` mode). Rows are matched by identity key; rows present
    /// on only one side are listed as new/gone instead of failing, so a
    /// baseline captured before a matrix extension stays usable.
    pub fn compare(&self, baseline_json: &str) -> String {
        let base = parse_baseline_cells(baseline_json);
        let mut matched = vec![false; base.len()];
        let mut s = String::from("=== vs baseline (per-row MIPS) ===\n");
        for cell in &self.cells {
            let key = cell.key();
            match base.iter().position(|(k, _)| *k == key) {
                Some(i) => {
                    matched[i] = true;
                    let (_, b) = base[i];
                    let cur = cell.mips();
                    let delta = if b > 0.0 {
                        format!("{:+.1}%", (cur - b) / b * 100.0)
                    } else {
                        "n/a".into()
                    };
                    s.push_str(&format!(
                        "{:<52} {:>9.2} -> {:>9.2} MIPS  ({})\n",
                        cell.label(),
                        b,
                        cur,
                        delta
                    ));
                }
                None => {
                    s.push_str(&format!(
                        "{:<52} {:>22.2} MIPS  [new — not in baseline]\n",
                        cell.label(),
                        cell.mips()
                    ));
                }
            }
        }
        for (i, (key, mips)) in base.iter().enumerate() {
            if !matched[i] {
                s.push_str(&format!(
                    "{:<52} {:>9.2} MIPS  [gone — baseline row not measured]\n",
                    key, mips
                ));
            }
        }
        s
    }

    /// Rows whose MIPS regressed more than `pct` percent against the
    /// baseline (the `--fail-threshold` gate). Only rows present on both
    /// sides participate; new/gone rows are reported by [`compare`] but
    /// never fail the gate (a baseline predating a matrix extension must
    /// stay usable).
    pub fn regressions(&self, baseline_json: &str, pct: f64) -> Vec<String> {
        let base = parse_baseline_cells(baseline_json);
        let mut out = Vec::new();
        for cell in &self.cells {
            let key = cell.key();
            let Some(&(_, b)) = base.iter().find(|(k, _)| *k == key) else {
                continue;
            };
            if b <= 0.0 {
                continue;
            }
            let cur = cell.mips();
            let delta = (cur - b) / b * 100.0;
            if delta < -pct {
                out.push(format!("{}: {:.2} -> {:.2} MIPS ({:+.1}%)", key, b, cur, delta));
            }
        }
        out
    }

    /// Machine-readable report (schema `r2vm-bench-engines-v1`).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"r2vm-bench-engines-v1\",\n");
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"runs\": {},\n", self.runs));
        s.push_str(&format!(
            "  \"host\": {{\"os\": \"{}\", \"arch\": \"{}\", \"cpus\": {}}},\n",
            std::env::consts::OS,
            std::env::consts::ARCH,
            self.host_cpus
        ));
        s.push_str("  \"cells\": [\n");
        for (i, cell) in self.cells.iter().enumerate() {
            let stats = &cell.engine_stats;
            s.push_str(&format!(
                "    {{\"workload\": \"{}\", \"mode\": \"{}\", \"pipeline\": \"{}\", \
                 \"memory\": \"{}\", \"dispatch\": \"{}\", \"harts\": {}, ",
                cell.workload, cell.mode, cell.pipeline, cell.memory, cell.dispatch, cell.harts
            ));
            if let Some((shards, quantum)) = cell.sharding {
                // Sharded-engine rows only: pre-sharding rows keep their
                // exact schema.
                s.push_str(&format!("\"shards\": {}, \"quantum\": {}, ", shards, quantum));
            }
            if cell.adaptive {
                // Adaptive-quantum twin rows only: fixed-quantum rows keep
                // their exact pre-adaptive schema.
                s.push_str("\"adaptive_quantum\": true, ");
            }
            if let Some(backend) = cell.backend {
                // Native-backend rows only: micro-op rows keep their exact
                // pre-native schema.
                s.push_str(&format!("\"backend\": \"{}\", ", backend));
            }
            if let Some(obs) = cell.obs {
                // Observability-ablation rows only: ordinary rows keep
                // their exact pre-observability schema.
                s.push_str(&format!("\"obs\": \"{}\", ", obs));
            }
            s.push_str(&format!(
                "\"mips\": {:.6}, \"best_secs\": {:.6}, \"mean_secs\": {:.6}, \"runs\": {}, ",
                cell.mips(),
                cell.measurement.best.as_secs_f64(),
                cell.measurement.mean.as_secs_f64(),
                cell.measurement.runs
            ));
            s.push_str(&format!(
                "\"insts\": {}, \"cycles\": {}, \"exit_ok\": {}, ",
                cell.insts,
                cell.cycles,
                cell.exit.is_some()
            ));
            s.push_str(&format!(
                "\"chain_hits\": {}, \"chain_misses\": {}, \"chain_hit_rate\": {:.6}, \
                 \"block_entries\": {}, \"blocks_translated\": {}, ",
                stats.chain_hits,
                stats.chain_misses,
                stats.chain_hit_rate(),
                stats.block_entries,
                stats.blocks_translated
            ));
            s.push_str("\"model_stats\": {");
            for (j, (k, v)) in cell.model_stats.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("\"{}\": {}", k, v));
            }
            s.push_str("}}");
            if i + 1 < self.cells.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ],\n");
        s.push_str("  \"skipped_cells\": [");
        for (i, label) in self.skipped.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\"", label));
        }
        s.push_str("],\n");
        let fmt_opt = |v: Option<f64>| match v {
            Some(x) => format!("{:.6}", x),
            None => "null".into(),
        };
        s.push_str(&format!(
            "  \"coremark_chain_mips\": {},\n",
            fmt_opt(self.coremark_chain_mips())
        ));
        s.push_str(&format!(
            "  \"coremark_lookup_mips\": {},\n",
            fmt_opt(self.coremark_lookup_mips())
        ));
        let speedup = match (self.coremark_chain_mips(), self.coremark_lookup_mips()) {
            (Some(c), Some(l)) if l > 0.0 => Some(c / l),
            _ => None,
        };
        s.push_str(&format!("  \"coremark_chain_speedup\": {},\n", fmt_opt(speedup)));
        s.push_str(&format!(
            "  \"coremark_native_mips\": {},\n",
            fmt_opt(self.coremark_native_mips())
        ));
        let native_speedup = match (self.coremark_chain_mips(), self.coremark_native_mips()) {
            (Some(m), Some(n)) if m > 0.0 => Some(n / m),
            _ => None,
        };
        s.push_str(&format!(
            "  \"coremark_native_speedup\": {},\n",
            fmt_opt(native_speedup)
        ));
        s.push_str(&format!(
            "  \"coremark_traced_mips\": {},\n",
            fmt_opt(self.coremark_traced_mips())
        ));
        let trace_overhead = match (self.coremark_chain_mips(), self.coremark_traced_mips()) {
            (Some(off), Some(on)) if on > 0.0 => Some(off / on),
            _ => None,
        };
        s.push_str(&format!(
            "  \"coremark_trace_overhead\": {},\n",
            fmt_opt(trace_overhead)
        ));
        s.push_str(&format!(
            "  \"coremark_o3_mips\": {},\n",
            fmt_opt(self.coremark_o3_mips())
        ));
        s.push_str(&format!(
            "  \"inorder_o3_mips_ratio\": {},\n",
            fmt_opt(self.inorder_o3_mips_ratio())
        ));
        s.push_str(&format!(
            "  \"shard_s1_q1024_mips\": {},\n",
            fmt_opt(self.shard_mips(1, 1024))
        ));
        s.push_str(&format!(
            "  \"shard_s4_q1024_mips\": {},\n",
            fmt_opt(self.shard_mips(4, 1024))
        ));
        s.push_str(&format!(
            "  \"shard_speedup_s4_q1024\": {},\n",
            fmt_opt(self.shard_speedup_q1024())
        ));
        s.push_str(&format!(
            "  \"adaptive_q_mips\": {},\n",
            fmt_opt(self.adaptive_q_mips())
        ));
        s.push_str(&format!(
            "  \"adaptive_q_speedup\": {}\n",
            fmt_opt(self.adaptive_q_speedup())
        ));
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One tiny cell end-to-end: the workload runs, exits cleanly, and
    /// chain-following dispatch serves the vast majority of entries.
    #[test]
    fn single_cell_runs_and_chains() {
        let cell = run_cell(
            "coremark-lite", 1, "lockstep", "simple", "atomic", false, None, false, None, false,
            1, true,
        )
        .expect("cell must run");
        assert!(cell.exit.is_some(), "workload must exit cleanly");
        assert!(cell.insts > 0);
        assert!(cell.measurement.work > 0);
        let stats = &cell.engine_stats;
        assert!(stats.block_entries > 0);
        assert!(
            stats.chain_hit_rate() > 0.5,
            "chain dispatch must dominate: {:?}",
            stats
        );
    }

    /// The lookup-dispatch ablation cell records zero chain hits.
    #[test]
    fn lookup_cell_has_no_chain_hits() {
        let cell = run_cell(
            "coremark-lite", 1, "lockstep", "simple", "atomic", true, None, false, None, false,
            1, true,
        )
        .expect("cell must run");
        assert_eq!(cell.engine_stats.chain_hits, 0);
        assert!(cell.engine_stats.chain_misses > 0);
        assert_eq!(cell.dispatch, "lookup");
    }

    /// A synthetic cell whose MIPS is exactly `mips` (1-second best run).
    fn synth_cell(workload: &str, mips: f64) -> Cell {
        let work = (mips * 1e6) as u64;
        Cell {
            workload: workload.into(),
            mode: "lockstep",
            pipeline: "simple",
            memory: "atomic",
            dispatch: "chain",
            harts: 1,
            sharding: None,
            adaptive: false,
            backend: None,
            obs: None,
            measurement: Measurement {
                name: workload.into(),
                best: std::time::Duration::from_secs(1),
                mean: std::time::Duration::from_secs(1),
                work,
                runs: 1,
            },
            insts: work,
            cycles: work,
            exit: Some(0),
            engine_stats: EngineStats::default(),
            model_stats: Vec::new(),
        }
    }

    /// `--fail-threshold` gates only rows present on both sides: a row
    /// missing from the baseline (printed as `[new]` by compare) must
    /// never count as a regression no matter how slow it is, or a baseline
    /// captured before a matrix extension would fail every CI run.
    #[test]
    fn regressions_skip_rows_missing_from_the_baseline() {
        let report = |cells: Vec<Cell>| BenchReport {
            quick: true,
            runs: 1,
            cells,
            skipped: Vec::new(),
            host_cpus: 1,
        };
        let baseline_json = report(vec![synth_cell("alpha", 100.0)]).to_json();
        let current = report(vec![synth_cell("alpha", 50.0), synth_cell("beta", 0.001)]);
        let regressed = current.regressions(&baseline_json, 10.0);
        assert_eq!(regressed.len(), 1, "only the matched row can regress: {:?}", regressed);
        assert!(regressed[0].contains("alpha"), "{:?}", regressed);
        // The glacial unmatched row is visible in compare() output — just
        // never a gate failure.
        let cmp = current.compare(&baseline_json);
        assert!(cmp.contains("[new"), "{}", cmp);
        // Within the threshold nothing regresses at all.
        assert!(current.regressions(&baseline_json, 60.0).is_empty());
    }

    /// Quick-matrix smoke on one workload + JSON structural checks.
    #[test]
    fn quick_report_schema_is_stable() {
        let opts = BenchOptions {
            runs: 1,
            quick: true,
            workload: Some("coremark-lite".into()),
            ..Default::default()
        };
        let report = run_bench(&opts);
        // 5 matrix cells + the lookup-dispatch ablation cell + the traced
        // observability-ablation cell + the inorder/o3 timing-tier pair,
        // plus (where the native backend is available) native twins of
        // the 4 lockstep rows and of the nochain ablation.
        let native_rows = if crate::dbt::native_available() { 5 } else { 0 };
        assert_eq!(
            report.cells.len(),
            MATRIX.len() + 4 + native_rows,
            "every cell must complete"
        );
        assert!(report.cells.iter().all(|c| c.exit.is_some()));
        assert!(report.coremark_chain_mips().is_some());
        assert!(report.coremark_lookup_mips().is_some());
        assert!(report.coremark_traced_mips().is_some());
        assert!(report.coremark_o3_mips().is_some());
        assert!(report.inorder_o3_mips_ratio().is_some());
        assert_eq!(report.coremark_native_mips().is_some(), native_rows > 0);
        // The traced twin retires the same guest work as its untraced
        // sibling — observability must not perturb execution.
        {
            let find = |obs: Option<&'static str>| {
                report
                    .cells
                    .iter()
                    .find(|c| {
                        c.memory == "atomic"
                            && c.mode == "lockstep"
                            && c.dispatch == "chain"
                            && c.backend.is_none()
                            && c.obs == obs
                    })
                    .expect("cell present")
            };
            assert_eq!(find(None).insts, find(Some("traced")).insts);
            assert_eq!(find(None).cycles, find(Some("traced")).cycles);
        }

        assert!(report.skipped.is_empty(), "skipped: {:?}", report.skipped);

        let json = report.to_json();
        assert!(json.contains("\"schema\": \"r2vm-bench-engines-v1\""));
        assert!(json.contains("\"skipped_cells\": []"));
        assert!(json.contains("\"dispatch\": \"chain\""));
        assert!(json.contains("\"dispatch\": \"lookup\""));
        assert!(json.contains("\"chain_hit_rate\""));
        assert!(json.contains("\"coremark_chain_mips\""));
        assert!(json.contains("\"coremark_lookup_mips\""));
        assert!(json.contains("\"coremark_chain_speedup\""));
        assert!(json.contains("\"coremark_native_mips\""));
        assert!(json.contains("\"coremark_traced_mips\""));
        assert!(json.contains("\"coremark_trace_overhead\""));
        assert!(json.contains("\"coremark_o3_mips\""));
        assert!(json.contains("\"inorder_o3_mips_ratio\""));
        // The o3 rows carry the ordinary schema with pipeline "o3" — no
        // new per-row keys.
        assert!(json.contains("\"pipeline\": \"o3\""));
        // The backend key appears on native rows only — micro-op rows keep
        // their exact pre-native schema; same for the obs key.
        assert_eq!(json.contains("\"backend\": \"native\""), native_rows > 0);
        assert!(!json.contains("\"backend\": \"microop\""));
        assert_eq!(json.matches("\"obs\": \"traced\"").count(), 1);

        // Self-comparison: every row matches its own baseline at ~0.0%
        // (the sign jitters with the 6-decimal JSON rounding).
        let cmp = report.compare(&json);
        assert!(!cmp.contains("[new"), "{}", cmp);
        assert!(!cmp.contains("[gone"), "{}", cmp);
        assert!(cmp.contains("0.0%"), "{}", cmp);
        // Crude structural checks (no JSON parser offline): balanced
        // braces/brackets, no trailing comma before a closing bracket.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert!(!json.contains(",\n  ]"));
        assert!(!json.contains(",}"));

        let table = report.table();
        assert!(table.contains("coremark-lite"));
        assert!(table.contains("coremark dispatch: chain"));
        assert!(table.contains("coremark tracing: off"));
        assert!(table.contains("coremark timing tier: inorder"));

        // The fail-threshold gate: self-comparison never regresses, and
        // the permissive-threshold sweep is trivially clean too.
        assert!(report.regressions(&json, 0.5).is_empty());
    }

    /// The `--fail-threshold` gate flags rows that regressed more than the
    /// threshold, ignores unmatched rows, and respects the cutoff.
    #[test]
    fn regressions_respect_threshold() {
        let cell = |key_mips: f64| Cell {
            workload: "w".into(),
            mode: "lockstep",
            pipeline: "simple",
            memory: "atomic",
            dispatch: "chain",
            harts: 1,
            sharding: None,
            adaptive: false,
            backend: None,
            obs: None,
            measurement: Measurement {
                name: "w".into(),
                best: Duration::from_secs(1),
                mean: Duration::from_secs(1),
                work: (key_mips * 1e6) as u64,
                runs: 1,
            },
            insts: 0,
            cycles: 0,
            exit: Some(0),
            engine_stats: EngineStats::default(),
            model_stats: Vec::new(),
        };
        let report = BenchReport {
            quick: true,
            runs: 1,
            cells: vec![cell(50.0)],
            skipped: Vec::new(),
            host_cpus: 1,
        };
        // Baseline says 100 MIPS for the same key: a 50% regression.
        let baseline = "{\"workload\": \"w\", \"mode\": \"lockstep\", \"pipeline\": \"simple\", \
                        \"memory\": \"atomic\", \"dispatch\": \"chain\", \"harts\": 1, \
                        \"mips\": 100.000000}\n";
        let hits = report.regressions(baseline, 10.0);
        assert_eq!(hits.len(), 1, "{:?}", hits);
        assert!(hits[0].contains("w lockstep/simple+atomic/chain/microop"), "{}", hits[0]);
        assert!(hits[0].contains("-50.0%"), "{}", hits[0]);
        assert!(report.regressions(baseline, 60.0).is_empty(), "cutoff respected");
        // A baseline without this row never fails the gate.
        assert!(report.regressions("{}", 0.0).is_empty());
    }

    /// The baseline line-parser keys every row dimension and defaults the
    /// backend on pre-native baselines.
    #[test]
    fn baseline_parsing_and_row_keys() {
        let baseline = "{\n  \"cells\": [\n    {\"workload\": \"w\", \"mode\": \"lockstep\", \
                        \"pipeline\": \"simple\", \"memory\": \"atomic\", \"dispatch\": \"chain\", \
                        \"harts\": 1, \"mips\": 25.500000, \"insts\": 5}\n  ]\n}\n";
        let cells = parse_baseline_cells(baseline);
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].0, "w lockstep/simple+atomic/chain/microop");
        assert!((cells[0].1 - 25.5).abs() < 1e-9);
        let row = "    {\"workload\": \"w\", \"mode\": \"sharded\", \"pipeline\": \"inorder\", \
                   \"memory\": \"cache\", \"dispatch\": \"chain\", \"harts\": 4, \"shards\": 2, \
                   \"quantum\": 64, \"backend\": \"native\", \"mips\": 1.000000}";
        assert_eq!(line_key(row).unwrap(), "w sharded[s2,q64]/inorder+cache/chain/native");
        // The adaptive-quantum marker keys the twin row distinctly from
        // its fixed-quantum sibling.
        let adaptive_row = "    {\"workload\": \"w\", \"mode\": \"sharded\", \
                   \"pipeline\": \"inorder\", \"memory\": \"cache\", \"dispatch\": \"chain\", \
                   \"harts\": 4, \"shards\": 4, \"quantum\": 1024, \
                   \"adaptive_quantum\": true, \"mips\": 1.000000}";
        assert_eq!(
            line_key(adaptive_row).unwrap(),
            "w sharded[s4,q1024,aq]/inorder+cache/chain/microop"
        );
        assert_eq!(parse_baseline_cells("not json at all"), Vec::<(String, f64)>::new());
    }

    /// The multicore workload produces the shard-scaling rows: the
    /// standard matrix plus SHARD_MATRIX sharded cells, all exiting
    /// cleanly, with the shards/quantum keys only on sharded rows.
    #[test]
    fn sharded_rows_present_and_schema_stable() {
        let opts = BenchOptions {
            runs: 1,
            quick: true,
            workload: Some("multicore".into()),
            ..Default::default()
        };
        let report = run_bench(&opts);
        assert_eq!(
            report.cells.len(),
            MATRIX.len() + SHARD_MATRIX.len() + 2,
            "matrix + shard-scaling + adaptive twin + o3 cells must all complete: {:?}",
            report.skipped
        );
        assert!(report.cells.iter().all(|c| c.exit.is_some()));
        // The dynamic-tier row: 4-hart o3 under MESI, clean exit with the
        // workload's expected result.
        let o3 = report.cells.iter().find(|c| c.pipeline == "o3").expect("o3 row present");
        assert_eq!((o3.mode, o3.memory, o3.harts), ("lockstep", "mesi", 4));
        assert_eq!(o3.exit, Some(crate::workloads::multicore::expected_sum(4, 5_000)));
        // Every sharded cell retired the same guest work (determinism of
        // the workload across shard/quantum points).
        let expected = crate::workloads::multicore::expected_sum(4, 5_000);
        for cell in report.cells.iter().filter(|c| c.sharding.is_some()) {
            assert_eq!(cell.exit, Some(expected), "cell {}", cell.label());
            assert_eq!(cell.mode, "sharded");
        }
        assert!(report.shard_mips(1, 1024).is_some());
        assert!(report.shard_mips(4, 1024).is_some());
        assert!(report.shard_speedup_q1024().is_some());
        // The adaptive twin: exactly one adaptive row, retiring the same
        // guest work as its fixed-quantum sibling, keyed distinctly.
        let adaptive: Vec<_> = report.cells.iter().filter(|c| c.adaptive).collect();
        assert_eq!(adaptive.len(), 1);
        assert_eq!(adaptive[0].sharding, Some((4, 1024)));
        assert_eq!(adaptive[0].exit, Some(expected));
        assert!(report.adaptive_q_mips().is_some());
        assert!(report.adaptive_q_speedup().is_some());
        let json = report.to_json();
        assert!(json.contains("\"shards\": 4, \"quantum\": 1024"));
        assert!(json.contains("\"shard_speedup_s4_q1024\""));
        assert!(json.contains("\"adaptive_q_mips\""));
        assert!(json.contains("\"adaptive_q_speedup\""));
        // The adaptive_quantum key appears on the twin row only.
        assert_eq!(json.matches("\"adaptive_quantum\": true").count(), 1);
        // Non-sharded rows keep the pre-sharding schema (no shard keys on
        // a lockstep row).
        assert!(!json
            .lines()
            .any(|l| l.contains("\"mode\": \"lockstep\"") && l.contains("\"shards\"")));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert!(report.table().contains("multicore sharded[s4,q1024]/inorder+cache"));
        assert!(report.table().contains("multicore sharded[s4,q1024,aq]/inorder+cache"));
        assert!(report.table().contains("multicore adaptive quantum @s4: fixed"));
        // Round-trip: the twin and its sibling match their own baseline
        // rows (distinct keys — neither reads as new/gone).
        let cmp = report.compare(&json);
        assert!(!cmp.contains("[new"), "{}", cmp);
        assert!(!cmp.contains("[gone"), "{}", cmp);
    }
}
