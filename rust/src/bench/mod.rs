//! Internal benchmarking harness (criterion is unavailable offline; see
//! DESIGN.md §3). Measures wall time over repeated runs and reports the
//! MIPS-style numbers the paper's Figure 5 uses. The [`engines`]
//! submodule drives the `bench` CLI subcommand's workload × engine ×
//! model matrix and writes `BENCH_engines.json`.

pub mod engines;
pub mod fleet;

pub use engines::{run_bench, BenchOptions, BenchReport};
pub use fleet::{FleetReport, InstanceResult, InstanceStats};

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Best (minimum) wall time across runs.
    pub best: Duration,
    pub mean: Duration,
    /// Work units (e.g. guest instructions) performed by the *best* run —
    /// paired with `best` so the reported rate is internally consistent
    /// even when per-run work varies.
    pub work: u64,
    pub runs: u32,
}

impl Measurement {
    /// Work units per second at the best run. 0 when nothing was measured
    /// (zero runs, zero work, or a sub-tick wall clock) — never inf/NaN.
    pub fn rate(&self) -> f64 {
        let secs = self.best.as_secs_f64();
        if self.runs == 0 || self.work == 0 || secs <= 0.0 {
            return 0.0;
        }
        self.work as f64 / secs
    }

    /// Millions of work units per second (MIPS when work = instructions).
    pub fn mips(&self) -> f64 {
        self.rate() / 1e6
    }

    pub fn row(&self) -> String {
        format!(
            "{:<34} {:>10.2} MIPS   best {:>9.3}s  mean {:>9.3}s  ({} insts, {} runs)",
            self.name,
            self.mips(),
            self.best.as_secs_f64(),
            self.mean.as_secs_f64(),
            self.work,
            self.runs
        )
    }
}

/// Run `f` (which returns the number of work units performed) `runs` times
/// after one warmup, reporting the best time paired with that same run's
/// work (per-run work can vary, so pairing the best time with another
/// run's work would misreport the rate). `runs == 0` yields an empty
/// measurement (zero time/work, rate 0) instead of a `Duration::MAX` best.
pub fn bench(name: &str, runs: u32, mut f: impl FnMut() -> u64) -> Measurement {
    match bench_with(name, runs, || (f(), ())) {
        Some((m, ())) => m,
        None => Measurement {
            name: name.into(),
            best: Duration::ZERO,
            mean: Duration::ZERO,
            work: 0,
            runs: 0,
        },
    }
}

/// The same warm-up / best-of-N / pair-best-with-its-own-work protocol as
/// [`bench`], for closures that also produce a payload (e.g. a full run
/// report): the payload returned is the *best run's*, so every derived
/// number describes the same run the measurement timed. This is the one
/// copy of the measurement protocol — [`bench`] delegates here. `None`
/// when `runs == 0` (nothing was measured, so there is no payload).
pub fn bench_with<T>(
    name: &str,
    runs: u32,
    mut f: impl FnMut() -> (u64, T),
) -> Option<(Measurement, T)> {
    if runs == 0 {
        return None;
    }
    let _ = f(); // warmup (fills code caches, page cache, etc.)
    let mut best: Option<(Duration, u64, T)> = None;
    let mut total = Duration::ZERO;
    for _ in 0..runs {
        let t0 = Instant::now();
        let (work, payload) = f();
        let dt = t0.elapsed();
        total += dt;
        if best.as_ref().map_or(true, |&(b, _, _)| dt < b) {
            best = Some((dt, work, payload));
        }
    }
    let (best_dt, work, payload) = best?;
    Some((
        Measurement { name: name.into(), best: best_dt, mean: total / runs, work, runs },
        payload,
    ))
}

/// Simple fixed-width table printer for benchmark reports.
pub fn print_table(title: &str, rows: &[Measurement]) {
    println!("\n=== {} ===", title);
    for m in rows {
        println!("{}", m.row());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_work() {
        let m = bench("spin", 3, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
            10_000
        });
        assert_eq!(m.work, 10_000);
        assert!(m.best <= m.mean);
        assert!(m.rate() > 0.0);
        assert!(m.row().contains("spin"));
    }

    #[test]
    fn best_time_pairs_with_its_own_work() {
        // Per-run work varies: a short run does little work, a long run
        // does a lot. The best (shortest) time must report the short
        // run's work, not whatever the last run happened to do.
        let mut call = 0u32;
        let m = bench("varying", 3, || {
            call += 1;
            match call {
                1 => 0,                  // warmup (excluded)
                2 => {
                    std::thread::sleep(Duration::from_millis(1));
                    100
                }
                _ => {
                    std::thread::sleep(Duration::from_millis(60));
                    1_000_000
                }
            }
        });
        assert_eq!(m.runs, 3);
        assert_eq!(m.work, 100, "best time must carry the fast run's work");
        assert!(m.best < Duration::from_millis(60));
        // The paired rate can never exceed fast-run work / fast-run time
        // misattributed from the slow runs' work.
        assert!(m.rate() < 100.0 / 0.001 + 1.0);
    }

    #[test]
    fn bench_with_returns_best_runs_payload() {
        // The payload handed back must belong to the same run as the
        // measurement's best time and work.
        let mut call = 0u32;
        let r = bench_with("payload", 2, || {
            call += 1;
            match call {
                1 => (0, "warmup"),
                2 => {
                    std::thread::sleep(Duration::from_millis(50));
                    (7, "slow")
                }
                _ => {
                    std::thread::sleep(Duration::from_millis(1));
                    (3, "fast")
                }
            }
        });
        let (m, payload) = r.expect("two runs measured");
        assert_eq!(payload, "fast");
        assert_eq!(m.work, 3, "work comes from the same run as the payload");
        assert_eq!(m.runs, 2);
        assert!(bench_with("none", 0, || (1, ())).is_none());
    }

    #[test]
    fn zero_runs_produces_empty_measurement() {
        let mut calls = 0u32;
        let m = bench("none", 0, || {
            calls += 1;
            42
        });
        assert_eq!(calls, 0, "no warmup either — nothing is measured");
        assert_eq!(m.runs, 0);
        assert_eq!(m.work, 0);
        assert_eq!(m.best, Duration::ZERO);
        assert_eq!(m.rate(), 0.0, "no Duration::MAX nonsense rates");
        assert!(m.mips().is_finite());
    }
}
