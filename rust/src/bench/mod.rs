//! Internal benchmarking harness (criterion is unavailable offline; see
//! DESIGN.md §3). Measures wall time over repeated runs and reports the
//! MIPS-style numbers the paper's Figure 5 uses.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Best (minimum) wall time across runs.
    pub best: Duration,
    pub mean: Duration,
    /// Work units (e.g. guest instructions) per run.
    pub work: u64,
    pub runs: u32,
}

impl Measurement {
    /// Work units per second at the best run.
    pub fn rate(&self) -> f64 {
        self.work as f64 / self.best.as_secs_f64()
    }

    /// Millions of work units per second (MIPS when work = instructions).
    pub fn mips(&self) -> f64 {
        self.rate() / 1e6
    }

    pub fn row(&self) -> String {
        format!(
            "{:<34} {:>10.2} MIPS   best {:>9.3}s  mean {:>9.3}s  ({} insts, {} runs)",
            self.name,
            self.mips(),
            self.best.as_secs_f64(),
            self.mean.as_secs_f64(),
            self.work,
            self.runs
        )
    }
}

/// Run `f` (which returns the number of work units performed) `runs` times
/// after one warmup, reporting the best time.
pub fn bench(name: &str, runs: u32, mut f: impl FnMut() -> u64) -> Measurement {
    let _ = f(); // warmup (fills code caches, page cache, etc.)
    let mut best = Duration::MAX;
    let mut total = Duration::ZERO;
    let mut work = 0;
    for _ in 0..runs {
        let t0 = Instant::now();
        work = f();
        let dt = t0.elapsed();
        total += dt;
        if dt < best {
            best = dt;
        }
    }
    Measurement { name: name.into(), best, mean: total / runs.max(1), work, runs }
}

/// Simple fixed-width table printer for benchmark reports.
pub fn print_table(title: &str, rows: &[Measurement]) {
    println!("\n=== {} ===", title);
    for m in rows {
        println!("{}", m.row());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_work() {
        let m = bench("spin", 3, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
            10_000
        });
        assert_eq!(m.work, 10_000);
        assert!(m.best <= m.mean);
        assert!(m.rate() > 0.0);
        assert!(m.row().contains("spin"));
    }
}
