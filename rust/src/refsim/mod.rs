//! Cycle-accurate reference simulator — the stand-in for the RTL
//! implementation the paper validates against (§4.1; substitution
//! documented in DESIGN.md §3).
//!
//! Unlike the DBT engine, which bakes cycle counts into translations
//! (per-block, hazard state reset at block entry) and filters memory
//! accesses through the L0, this reference:
//!
//!  * tracks an absolute-time *scoreboard* per register, so hazards are
//!    modelled exactly, across basic-block boundaries;
//!  * invokes the memory model on **every** access (`force_cold`), so
//!    replacement state sees the full access stream;
//!  * resolves branches with the actual outcome against the same static
//!    predictor.
//!
//! The two implementations are structurally independent: agreement within
//! the paper's reported error bounds (<1% pipeline-only, ~10% with MESI)
//! is therefore meaningful validation, and E1/E3/E4 measure exactly this.

use crate::asm::Image;
use crate::interp::{poll_interrupt, ExitReason};
use crate::isa::csr::{EXC_ECALL_M, EXC_ECALL_S, EXC_ECALL_U};
use crate::isa::op::{MulOp, Op};
use crate::isa::decode;
use crate::sys::exec::{exec_op, fetch_raw, Flow};
use crate::sys::hart::Hart;
use crate::sys::loader::load_flat;
use crate::sys::{handle_ecall, System};

const MISPREDICT: u64 = 2;
const REDIRECT: u64 = 1;

/// Per-core pipeline timing state.
struct CoreTiming {
    /// Cycle at which each register's value is available for forwarding.
    ready: [u64; 32],
    /// Earliest cycle the next instruction may issue (EX occupancy).
    next_issue: u64,
}

impl CoreTiming {
    fn new() -> CoreTiming {
        CoreTiming { ready: [0; 64 / 2], next_issue: 1 }
    }
}

/// The reference simulator.
pub struct RefSim {
    pub harts: Vec<Hart>,
    pub sys: System,
    timing: Vec<CoreTiming>,
}

impl RefSim {
    pub fn new(mut sys: System) -> RefSim {
        // The reference sees every access (exact replacement, no L0).
        sys.force_cold = true;
        let n = sys.num_harts;
        RefSim {
            harts: (0..n).map(Hart::new).collect(),
            timing: (0..n).map(|_| CoreTiming::new()).collect(),
            sys,
        }
    }

    pub fn load(&mut self, image: &Image) {
        let entry = load_flat(&self.sys, image);
        for h in &mut self.harts {
            h.pc = entry;
        }
    }

    fn op_srcs_ready(&self, h: usize, op: &Op) -> u64 {
        let (s1, s2) = op.srcs();
        let mut t = 0;
        if let Some(r) = s1 {
            t = t.max(self.timing[h].ready[r as usize]);
        }
        if let Some(r) = s2 {
            t = t.max(self.timing[h].ready[r as usize]);
        }
        t
    }

    /// Execute one instruction on hart `h`, advancing its cycle clock
    /// per the 5-stage model. Returns false if the hart cannot progress.
    fn step(&mut self, h: usize) -> bool {
        if self.harts[h].halted {
            return false;
        }
        poll_interrupt(&mut self.harts[h], &mut self.sys);
        if self.harts[h].wfi {
            return false;
        }

        let pc = self.harts[h].pc;
        // Memory-model cycles (fetch + data) accumulate in hart.pending.
        self.harts[h].pending = 0;
        let raw = match fetch_raw(&mut self.harts[h], &mut self.sys, pc) {
            Ok(r) => r,
            Err(trap) => {
                let hart = &mut self.harts[h];
                hart.pc = hart.take_trap(trap, pc);
                return true;
            }
        };
        let fetch_cycles = std::mem::take(&mut self.harts[h].pending);
        let (op, len) = decode(raw);
        let npc = pc.wrapping_add(len);

        // Issue: in-order, operands via forwarding network.
        let t = &self.timing[h];
        let issue = t.next_issue.max(self.op_srcs_ready(h, &op)) + fetch_cycles;

        let flow = match exec_op(&mut self.harts[h], &mut self.sys, &op, pc, npc) {
            Ok(flow) => {
                self.harts[h].instret += 1;
                flow
            }
            Err(trap) => {
                let mem_cycles = std::mem::take(&mut self.harts[h].pending);
                let is_ecall = matches!(trap.cause, EXC_ECALL_U | EXC_ECALL_S | EXC_ECALL_M);
                if is_ecall && handle_ecall(&mut self.harts[h], &mut self.sys) {
                    self.harts[h].instret += 1;
                    self.harts[h].pending = 0;
                    self.harts[h].pc = npc;
                } else {
                    let hart = &mut self.harts[h];
                    hart.pc = hart.take_trap(trap, pc);
                }
                let t = &mut self.timing[h];
                t.next_issue = issue + 1 + mem_cycles;
                self.harts[h].cycle = t.next_issue;
                return true;
            }
        };
        let mem_cycles = std::mem::take(&mut self.harts[h].pending);

        // ---- writeback / ready-time bookkeeping ------------------------------
        let t = &mut self.timing[h];
        let mut next_issue = issue + 1;
        match op {
            Op::Load { rd, .. } | Op::Lr { rd, .. } | Op::Amo { rd, .. } => {
                // Load-to-use 2 (hit) + memory-model stall cycles.
                next_issue += mem_cycles;
                if rd != 0 {
                    t.ready[rd as usize] = issue + 2 + mem_cycles;
                }
            }
            Op::Store { .. } | Op::Sc { .. } => {
                next_issue += mem_cycles;
                if let Op::Sc { rd, .. } = op {
                    if rd != 0 {
                        t.ready[rd as usize] = issue + 1;
                    }
                }
            }
            Op::Mul { op: mop, rd, .. } => match mop {
                MulOp::Mul | MulOp::Mulh | MulOp::Mulhsu | MulOp::Mulhu => {
                    if rd != 0 {
                        t.ready[rd as usize] = issue + 3;
                    }
                }
                _ => {
                    // Unpipelined divider: EX busy for the full latency.
                    next_issue = issue + 20;
                    if rd != 0 {
                        t.ready[rd as usize] = issue + 20;
                    }
                }
            },
            _ => {
                if let Some(rd) = op.rd() {
                    t.ready[rd as usize] = issue + 1;
                }
            }
        }

        // ---- control flow / static prediction ----------------------------------
        let (new_pc, redirect) = match flow {
            Flow::Next => {
                let mispredicted = matches!(op, Op::Branch { imm, .. } if imm < 0);
                (npc, if mispredicted { MISPREDICT } else { 0 })
            }
            Flow::Taken => {
                let imm = match op {
                    Op::Branch { imm, .. } => imm,
                    _ => unreachable!(),
                };
                let target = pc.wrapping_add(imm as i64 as u64);
                let predicted = imm < 0;
                let mut pen = if predicted { REDIRECT } else { MISPREDICT };
                pen += (target & 3 != 0) as u64;
                (target, pen)
            }
            Flow::Jump(target) => {
                let pen = match op {
                    Op::Jal { .. } => REDIRECT + (target & 3 != 0) as u64,
                    Op::Jalr { .. } => MISPREDICT,
                    // mret/sret and other redirects: full flush.
                    _ => MISPREDICT,
                };
                (target, pen)
            }
            Flow::Wfi => {
                self.harts[h].wfi = true;
                (npc, 0)
            }
        };
        t.next_issue = next_issue + redirect;
        self.harts[h].pc = new_pc;
        self.harts[h].cycle = t.next_issue;

        if self.harts[h].effects.any() {
            // No translated state to flush in the reference; just clear.
            if self.harts[h].effects.sfence {
                self.sys.model.flush_hart(&mut self.sys.l0, h);
            }
            self.harts[h].effects.clear();
        }
        true
    }

    /// Run to completion in lockstep (min-cycle core first).
    pub fn run(&mut self, max_insts: u64) -> ExitReason {
        let mut total = 0u64;
        loop {
            if let Some(code) = self.sys.exit.or(self.sys.bus.simio.exit_code) {
                return ExitReason::Exited(code);
            }
            if total >= max_insts {
                return ExitReason::StepLimit;
            }
            // min-cycle scheduling, same discipline as the fiber engine
            let mut best = None;
            for (i, hart) in self.harts.iter().enumerate() {
                if hart.halted || hart.wfi {
                    continue;
                }
                if best.map_or(true, |b: usize| hart.cycle < self.harts[b].cycle) {
                    best = Some(i);
                }
            }
            let Some(h) = best else {
                // all WFI: advance to the next timer deadline
                match self.sys.bus.clint.next_timer_deadline() {
                    Some(t) => {
                        let mut woke = false;
                        for i in 0..self.harts.len() {
                            if self.harts[i].wfi {
                                self.harts[i].cycle = self.harts[i].cycle.max(t);
                                self.timing[i].next_issue =
                                    self.timing[i].next_issue.max(t);
                                poll_interrupt(&mut self.harts[i], &mut self.sys);
                                woke |= !self.harts[i].wfi;
                            }
                        }
                        if !woke {
                            return ExitReason::Deadlock;
                        }
                        continue;
                    }
                    None => return ExitReason::Deadlock,
                }
            };
            if self.step(h) {
                total += 1;
            }
        }
    }

    pub fn cycles(&self, h: usize) -> u64 {
        self.harts[h].cycle
    }
}

/// Convenience: run `image` on the reference with a memory model by name.
pub fn run_ref(image: &Image, harts: usize, memory: &str, max_insts: u64) -> (ExitReason, Vec<(u64, u64)>) {
    let mut cfg = crate::coordinator::SimConfig::default();
    cfg.harts = harts;
    cfg.memory = memory.into();
    let sys = crate::coordinator::build_system(&cfg);
    let mut r = RefSim::new(sys);
    r.load(image);
    let exit = r.run(max_insts);
    (exit, r.harts.iter().map(|h| (h.cycle, h.instret)).collect())
}

/// Quick E1-style check used by the `validate` CLI command: coremark-lite
/// on the DBT InOrder model vs this reference, both with atomic memory.
pub fn validate_inorder_quick() -> String {
    let img = crate::workloads::coremark::build(5);
    let (re, rref) = run_ref(&img, 1, "atomic", 200_000_000);
    let mut cfg = crate::coordinator::SimConfig::default();
    cfg.pipeline = "inorder".into();
    cfg.max_insts = 200_000_000;
    let dbt = crate::coordinator::run_image(&cfg, &img);
    let (rc, ri) = rref[0];
    let (dc, di) = dbt.per_hart[0];
    let err = (dc as f64 - rc as f64).abs() / rc as f64 * 100.0;
    format!(
        "E1 pipeline validation (coremark-lite, InOrder vs per-cycle reference)\n\
         ref: exit={:?} cycles={} insts={} (CPI {:.3})\n\
         dbt: exit={:?} cycles={} insts={} (CPI {:.3})\n\
         cycle error: {:.3}% (paper: <1%)\n",
        re,
        rc,
        ri,
        rc as f64 / ri as f64,
        dbt.exit,
        dc,
        di,
        dc as f64 / di as f64,
        err
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn functional_agreement_with_dbt() {
        let img = workloads::coremark::build(2);
        let want = workloads::coremark::expected_checksum(2);
        let (exit, _) = run_ref(&img, 1, "atomic", 100_000_000);
        assert_eq!(exit, ExitReason::Exited(want));
    }

    #[test]
    fn e1_inorder_accuracy_within_one_percent() {
        // The headline §4.1 claim: DBT InOrder vs cycle-accurate reference
        // differ by < 1% on the CoreMark-role workload.
        let img = workloads::coremark::build(3);
        let (_, rref) = run_ref(&img, 1, "atomic", 200_000_000);
        let mut cfg = crate::coordinator::SimConfig::default();
        cfg.pipeline = "inorder".into();
        let dbt = crate::coordinator::run_image(&cfg, &img);
        let (rc, _) = rref[0];
        let (dc, _) = dbt.per_hart[0];
        let err = (dc as f64 - rc as f64).abs() / rc as f64;
        assert!(err < 0.01, "pipeline error {:.4}% exceeds 1%", err * 100.0);
    }

    #[test]
    fn load_use_visible_in_cpi() {
        // A chain of dependent loads must push reference CPI above 1.
        use crate::asm::*;
        let mut a = Assembler::new(crate::mem::DRAM_BASE);
        let data = a.new_label();
        a.la(T0, data);
        a.sd(T0, T0, 0);
        a.li(T1, 1000);
        let top = a.here();
        a.ld(T0, T0, 0); // load
        a.ld(T0, T0, 0); // immediately dependent load => stall each
        a.addi(T1, T1, -1);
        a.bnez(T1, top);
        a.li(A0, 0);
        a.li(A7, 93);
        a.ecall();
        a.align(8);
        a.bind(data);
        a.d64(0);
        let img = a.finish();
        let (_, r) = run_ref(&img, 1, "atomic", 10_000_000);
        let (cycles, insts) = r[0];
        let cpi = cycles as f64 / insts as f64;
        assert!(cpi > 1.2, "dependent loads must stall: CPI={:.3}", cpi);
    }

    #[test]
    fn spinlock_mesi_reference_runs() {
        let img = workloads::spinlock::build(2, 100);
        let (exit, _) = run_ref(&img, 2, "mesi", 100_000_000);
        assert_eq!(exit, ExitReason::Exited(200));
    }
}
