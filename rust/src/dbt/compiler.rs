//! The DBT compiler: translates one guest basic block into a micro-op
//! trace, invoking the pipeline model's hooks per instruction so cycle
//! counts are baked into the translation (paper §3.2, Listing 1).

use super::block::{Block, BlockProf, ChainLink, CrossPageStub, Step, Term, TermKind};
use crate::isa::decode::{decode16, decode32, inst_len};
use crate::isa::op::Op;
use crate::pipeline::{InstDesc, PipelineModel, Tier};
use crate::sys::Trap;

/// Maximum instructions translated into one block (long straight-line code
/// is split; the tail continues in the next block).
pub const MAX_BLOCK_INSTS: usize = 64;

/// Translation-time state exposed to pipeline-model hooks.
///
/// Mirrors the paper's `DbtCompiler` parameter (Listing 1): hooks call
/// [`DbtCompiler::insert_cycle_count`] to charge cycles for the
/// instruction being translated.
pub struct DbtCompiler {
    /// Cycles charged to the instruction currently being translated.
    cur_cycles: u32,
    /// PC of the instruction currently being translated.
    pub cur_pc: u64,
    /// Whether the current instruction starts the block.
    pub at_block_start: bool,
}

impl DbtCompiler {
    pub fn new(pc: u64) -> DbtCompiler {
        DbtCompiler { cur_cycles: 0, cur_pc: pc, at_block_start: true }
    }

    /// Charge `n` cycles for the current instruction (Listing 1).
    #[inline]
    pub fn insert_cycle_count(&mut self, n: u32) {
        self.cur_cycles += n;
    }

    /// Drain the cycles charged for the current instruction.
    pub fn take_cycles(&mut self) -> u32 {
        std::mem::take(&mut self.cur_cycles)
    }
}

/// Reads guest instruction memory at translation time. Must be side-effect
/// free with respect to timing (the runtime I-cache checks are generated
/// separately, §3.4.2).
pub trait FetchProbe {
    fn fetch_u16(&mut self, vaddr: u64) -> Result<u16, Trap>;
}

impl<F: FnMut(u64) -> Result<u16, Trap>> FetchProbe for F {
    fn fetch_u16(&mut self, vaddr: u64) -> Result<u16, Trap> {
        self(vaddr)
    }
}

/// Translate the basic block starting at `pc`.
///
/// `icache_line_shift` controls where runtime L0 I-cache checks are
/// generated: one at block entry plus one per crossed line.
pub fn translate(
    fetch: &mut dyn FetchProbe,
    model: &mut dyn PipelineModel,
    pc: u64,
    icache_line_shift: u32,
) -> Result<Block, Trap> {
    let mut steps: Vec<Step> = Vec::new();
    let mut icache_checks = vec![pc];
    let mut cross_page: Option<CrossPageStub> = None;
    let mut cur = pc;
    let mut comp = DbtCompiler::new(pc);
    // Dynamic-tier models charge nothing at translation time; instead the
    // block carries a descriptor per instruction for the runtime retire
    // hook (DESIGN.md §14).
    let dynamic = model.tier() == Tier::Dynamic;
    let mut dtrace: Vec<InstDesc> = Vec::new();
    model.block_start(&mut comp);

    loop {
        // Line-crossing check for the runtime I-cache accesses.
        if cur != pc && (cur >> icache_line_shift) != ((cur - 2) >> icache_line_shift) {
            icache_checks.push(cur);
        }

        let lo = fetch.fetch_u16(cur)?;
        let len = inst_len(lo);
        let (op, raw_len) = if len == 2 {
            (decode16(lo), 2u8)
        } else {
            // A 4-byte instruction whose second half lies on the next page
            // gets a cross-page guard stub (§3.1).
            let hi_addr = cur + 2;
            let hi = fetch.fetch_u16(hi_addr)?;
            if cur & 0xfff == 0xffe {
                cross_page = Some(CrossPageStub { vaddr: hi_addr, expected: hi });
            }
            (decode32((lo as u32) | ((hi as u32) << 16)), 4u8)
        };

        comp.cur_pc = cur;
        let pc_off = (cur - pc) as u16;
        let compressed = raw_len == 2;

        if op.ends_block() || steps.len() + 1 >= MAX_BLOCK_INSTS {
            // Terminator.
            let kind = match op {
                Op::Jal { .. } => TermKind::Jump {
                    target: match op {
                        Op::Jal { imm, .. } => cur.wrapping_add(imm as i64 as u64),
                        _ => unreachable!(),
                    },
                },
                Op::Jalr { .. } => TermKind::IndirectJump,
                Op::Branch { .. } => TermKind::Branch,
                _ => TermKind::Fallthrough,
            };
            // The two hooks are *alternatives* (Listing 1): in the paper's
            // generated code a taken branch leaves the block through the
            // after_taken_branch insertion and never reaches the sequential
            // after_instruction one.
            model.after_instruction(&mut comp, &op, compressed);
            let cycles_nt = comp.take_cycles();
            model.after_taken_branch(&mut comp, &op, compressed);
            let cycles_taken = comp.take_cycles();
            let sync = op.is_mem() || op.is_system();
            let term = Term { op, pc_off, len: raw_len, kind, cycles_nt, cycles_taken, sync };
            if dynamic {
                dtrace.push(InstDesc::from_op(&op, pc_off, raw_len));
            }
            return Ok(Block {
                start: pc,
                end: cur + raw_len as u64,
                steps,
                term,
                icache_checks,
                cross_page,
                chain_taken: ChainLink::empty(),
                chain_seq: ChainLink::empty(),
                dtrace,
                prof: BlockProf::default(),
            });
        }

        model.after_instruction(&mut comp, &op, compressed);
        let cycles = comp.take_cycles();
        let sync = op.is_mem() || op.is_system();
        if dynamic {
            dtrace.push(InstDesc::from_op(&op, pc_off, raw_len));
        }
        steps.push(Step { op, pc_off, len: raw_len, cycles, sync });
        comp.at_block_start = false;
        cur += raw_len as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::SimpleModel;

    /// Probe over a flat byte image at base 0.
    fn probe(bytes: Vec<u8>) -> impl FnMut(u64) -> Result<u16, Trap> {
        move |addr: u64| {
            let i = addr as usize;
            Ok(u16::from_le_bytes([bytes[i], bytes[i + 1]]))
        }
    }

    fn asm_bytes(build: impl FnOnce(&mut crate::asm::Assembler)) -> Vec<u8> {
        let mut a = crate::asm::Assembler::new(0);
        build(&mut a);
        a.finish().bytes
    }

    #[test]
    fn translate_simple_block() {
        use crate::asm::*;
        let bytes = asm_bytes(|a| {
            a.addi(A0, A0, 1); // step
            a.addi(A1, A1, 2); // step
            let l = a.new_label();
            a.beqz(A0, l); // terminator
            a.bind(l);
        });
        let mut f = probe(bytes);
        let mut m = SimpleModel::default();
        let b = translate(&mut f, &mut m, 0, 6).unwrap();
        assert_eq!(b.steps.len(), 2);
        assert_eq!(b.term.kind, TermKind::Branch);
        assert_eq!(b.end, 12);
        // Simple model: 1 cycle per instruction, taken or not.
        assert!(b.steps.iter().all(|s| s.cycles == 1));
        assert_eq!(b.term.cycles_nt, 1);
        assert_eq!(b.term.cycles_taken, 1);
        assert_eq!(b.icache_checks, vec![0]);
    }

    #[test]
    fn sync_flag_on_memory_and_csr() {
        use crate::asm::*;
        let bytes = asm_bytes(|a| {
            a.addi(A0, A0, 1);
            a.ld(A1, A0, 0); // memory => sync
            a.csrr(A2, crate::isa::csr::CSR_MCYCLE); // csr => sync
            a.ret();
        });
        let mut f = probe(bytes);
        let mut m = SimpleModel::default();
        let b = translate(&mut f, &mut m, 0, 6).unwrap();
        assert!(!b.steps[0].sync);
        assert!(b.steps[1].sync);
        assert!(b.steps[2].sync);
        assert_eq!(b.term.kind, TermKind::IndirectJump);
    }

    #[test]
    fn icache_checks_on_line_crossing() {

        // 20 x 4-byte nops cross a 64-byte line once (at offset 64).
        let bytes = asm_bytes(|a| {
            for _ in 0..20 {
                a.nop();
            }
            a.ret();
        });
        let mut f = probe(bytes);
        let mut m = SimpleModel::default();
        let b = translate(&mut f, &mut m, 0, 6).unwrap();
        assert_eq!(b.icache_checks, vec![0, 64]);
    }

    #[test]
    fn long_block_is_split() {

        let bytes = asm_bytes(|a| {
            for _ in 0..100 {
                a.nop();
            }
            a.ret();
        });
        let mut f = probe(bytes);
        let mut m = SimpleModel::default();
        let b = translate(&mut f, &mut m, 0, 6).unwrap();
        assert_eq!(b.steps.len(), MAX_BLOCK_INSTS - 1);
        assert_eq!(b.term.kind, TermKind::Fallthrough);
        assert_eq!(b.seq_target(), (MAX_BLOCK_INSTS as u64) * 4);
    }

    #[test]
    fn cross_page_stub_recorded() {
        use crate::asm::*;
        // Place a 4-byte instruction at 0xffe.
        let mut bytes = vec![0u8; 0x1000 + 8];
        let insn = asm_bytes(|a| {
            a.addi(A0, A0, 1);
            a.ret();
        });
        bytes[0xffe..0xffe + insn.len()].copy_from_slice(&insn);
        let mut f = probe(bytes);
        let mut m = SimpleModel::default();
        let b = translate(&mut f, &mut m, 0xffe, 6).unwrap();
        let stub = b.cross_page.expect("cross-page stub");
        assert_eq!(stub.vaddr, 0x1000);
        // expected = upper half of `addi a0, a0, 1`
        let enc = crate::isa::encode(crate::isa::Op::AluImm {
            op: crate::isa::AluOp::Add,
            word: false,
            rd: 10,
            rs1: 10,
            imm: 1,
        });
        assert_eq!(stub.expected, (enc >> 16) as u16);
    }

    #[test]
    fn dynamic_model_records_dtrace_and_bakes_no_cycles() {
        use crate::asm::*;
        use crate::pipeline::{by_name, OpClass};
        let bytes = asm_bytes(|a| {
            a.addi(A0, A0, 1);
            a.ld(A1, A0, 8);
            let l = a.new_label();
            a.beqz(A0, l);
            a.bind(l);
        });
        let mut f = probe(bytes.clone());
        let mut m = by_name("o3").unwrap();
        let b = translate(&mut f, &mut *m, 0, 6).unwrap();
        // One descriptor per step plus the terminator.
        assert_eq!(b.dtrace.len(), b.steps.len() + 1);
        assert_eq!(b.dtrace[0].class, OpClass::Alu);
        assert_eq!(b.dtrace[1].class, OpClass::Load);
        assert_eq!(b.dtrace[1].imm, 8);
        assert_eq!(b.dtrace[2].class, OpClass::Branch);
        assert_eq!(b.dtrace[2].pc_off, b.term.pc_off);
        // Dynamic models bake zero cycles into the translation.
        assert!(b.steps.iter().all(|s| s.cycles == 0));
        assert_eq!(b.term.cycles_nt, 0);
        assert_eq!(b.term.cycles_taken, 0);
        // Static models record no dtrace.
        let mut f = probe(bytes);
        let mut m = SimpleModel::default();
        let b = translate(&mut f, &mut m, 0, 6).unwrap();
        assert!(b.dtrace.is_empty());
    }

    #[test]
    fn compressed_instructions_tracked() {
        // c.li a0, 1 (2 bytes) then ret
        let mut bytes = 0x4505u16.to_le_bytes().to_vec();
        bytes.extend(asm_bytes(|a| a.ret()));
        bytes.extend([0, 0]);
        let mut f = probe(bytes);
        let mut m = SimpleModel::default();
        let b = translate(&mut f, &mut m, 0, 6).unwrap();
        assert_eq!(b.steps.len(), 1);
        assert_eq!(b.steps[0].len, 2);
        assert_eq!(b.term.pc_off, 2);
    }
}
