//! Shared warm-start code seed (fleet mode).
//!
//! Translation is the dominant cold-start cost when the same checkpoint is
//! restored many times: every instance retranslates the same guest code.
//! A [`CodeSeed`] is the immutable, `Arc`-shareable essence of a warmed-up
//! code cache — the translated micro-op payload of every block, *without*
//! any per-instance mutable residue (chain links, profiling cells, native
//! code). A fleet warms one instance, harvests its caches into a seed, and
//! hands the `Arc` to every subsequent instance; each cache materialises
//! blocks from the seed on lookup miss instead of retranslating
//! ([`crate::dbt::CodeCache::get`]).
//!
//! Safety argument (why sharing translations cannot leak state between
//! instances):
//!  - A [`SeedBlock`] carries only data that is a pure function of the
//!    guest bytes, the pipeline model and the L0 I-cache line shift — the
//!    exact inputs of `dbt::compiler::translate`. Pipeline hooks run at
//!    translation time and reset per block, so a materialised block is
//!    bit-identical to the one the instance would have translated itself.
//!  - The seed is stamped with the pipeline name and line shift it was
//!    built under; installation refuses mismatched caches, and any cache
//!    flush (fence.i, satp write, SIMCTRL model switch) drops the seed —
//!    the flush invalidates the premise the seed was built under.
//!  - Mutable state (chain links, profiling counters) is created fresh at
//!    materialisation, so no writes ever flow between instances.

use super::block::{Block, BlockProf, ChainLink, CrossPageStub, Step, Term};
use super::cache::PcHasher;
use std::collections::HashMap;
use std::hash::BuildHasherDefault;

/// The immutable translation payload of one [`Block`] — everything
/// `translate` produced, nothing the dispatch loop mutates.
pub struct SeedBlock {
    pub start: u64,
    pub end: u64,
    pub steps: Vec<Step>,
    pub term: Term,
    pub icache_checks: Vec<u64>,
    pub cross_page: Option<CrossPageStub>,
    /// Dynamic-tier descriptor trace (empty for static models).
    pub dtrace: Vec<crate::pipeline::InstDesc>,
}

impl SeedBlock {
    pub fn from_block(b: &Block) -> SeedBlock {
        SeedBlock {
            start: b.start,
            end: b.end,
            steps: b.steps.clone(),
            term: b.term,
            icache_checks: b.icache_checks.clone(),
            cross_page: b.cross_page,
            dtrace: b.dtrace.clone(),
        }
    }

    /// Mint a live [`Block`] with fresh (empty) chain links and zeroed
    /// profiling cells.
    pub fn instantiate(&self) -> Block {
        Block {
            start: self.start,
            end: self.end,
            steps: self.steps.clone(),
            term: self.term,
            icache_checks: self.icache_checks.clone(),
            cross_page: self.cross_page,
            chain_taken: ChainLink::empty(),
            chain_seq: ChainLink::empty(),
            dtrace: self.dtrace.clone(),
            prof: BlockProf::default(),
        }
    }
}

/// A read-only, `Arc`-shareable set of translations keyed exactly like a
/// [`crate::dbt::CodeCache`] (`cache_key(pc, prv)`), stamped with the
/// translation inputs it is valid for.
pub struct CodeSeed {
    /// Pipeline model the blocks were translated under.
    pub pipeline: &'static str,
    /// Configuration digest of that model
    /// ([`crate::pipeline::PipelineModel::config_digest`]): two same-named
    /// models with different parameters must never share translations
    /// (dynamic models bake their parameters into the descriptor-trace
    /// interpretation and future static models could bake latencies).
    pub model_digest: u64,
    /// L0 I-cache line shift baked into the icache check lists.
    pub line_shift: u32,
    map: HashMap<u64, u32, BuildHasherDefault<PcHasher>>,
    blocks: Vec<SeedBlock>,
}

impl CodeSeed {
    pub fn new(pipeline: &'static str, model_digest: u64, line_shift: u32) -> CodeSeed {
        CodeSeed { pipeline, model_digest, line_shift, map: HashMap::default(), blocks: Vec::new() }
    }

    /// Contribute one translation under `key`. First writer wins: when
    /// several warmed caches carry the same key (SMP harts running the
    /// same code), the copies are identical by the purity argument above,
    /// so keeping the first is arbitrary but sound.
    pub fn add(&mut self, key: u64, block: &Block) {
        if !self.map.contains_key(&key) {
            self.map.insert(key, self.blocks.len() as u32);
            self.blocks.push(SeedBlock::from_block(block));
        }
    }

    #[inline]
    pub fn lookup(&self, key: u64) -> Option<&SeedBlock> {
        self.map.get(&key).map(|&i| &self.blocks[i as usize])
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_block() -> Block {
        Block {
            start: 0x1000,
            end: 0x1008,
            steps: Vec::new(),
            term: Term {
                op: crate::isa::op::Op::Jal { rd: 0, imm: 0 },
                pc_off: 4,
                len: 4,
                kind: super::super::block::TermKind::Jump { target: 0x1000 },
                cycles_nt: 1,
                cycles_taken: 1,
                sync: false,
            },
            icache_checks: vec![0x1000],
            cross_page: None,
            chain_taken: ChainLink::empty(),
            chain_seq: ChainLink::empty(),
            dtrace: Vec::new(),
            prof: BlockProf::default(),
        }
    }

    #[test]
    fn first_writer_wins_and_instantiation_is_fresh() {
        let mut seed = CodeSeed::new("simple", 0, 6);
        assert!(seed.is_empty());
        let b = demo_block();
        b.chain_taken.install(5, 99); // residue that must NOT be shared
        b.prof.exec.set(1234);
        seed.add(7, &b);
        seed.add(7, &demo_block());
        assert_eq!(seed.len(), 1, "duplicate key ignored");
        let minted = seed.lookup(7).unwrap().instantiate();
        assert_eq!(minted.start, 0x1000);
        assert_eq!(minted.icache_checks, vec![0x1000]);
        assert!(minted.chain_taken.is_empty(), "chain links start empty");
        assert_eq!(minted.prof.exec.get(), 0, "profiling cells start zeroed");
        assert!(seed.lookup(8).is_none());
    }
}
