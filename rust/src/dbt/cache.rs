//! Per-hart code caches (paper §3.1).
//!
//! Each hart owns its cache so per-hart pipeline models (heterogeneous
//! cores, §3.5) can generate different code, and no synchronisation is
//! needed to modify it — the design decision the paper takes in contrast to
//! Cota et al.'s shared cache.

use super::block::{Block, BlockId};
use super::seed::CodeSeed;
use crate::obs::ProfileTable;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::Arc;

/// Multiply-xor hasher for PC keys (std SipHash is needlessly slow on the
/// block-lookup path; no untrusted keys here).
#[derive(Default)]
pub struct PcHasher(u64);

impl Hasher for PcHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u64(b as u64);
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        // splitmix64-style finalisation.
        let mut x = self.0 ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        self.0 = x;
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.write_u64(v as u64);
    }
}

type PcMap = HashMap<u64, BlockId, BuildHasherDefault<PcHasher>>;

/// A per-hart translated-code cache.
pub struct CodeCache {
    blocks: Vec<Block>,
    /// pc | (prv << 62) → block id. Translations depend on the privilege
    /// mode (fetch permissions); satp changes flush the whole cache.
    map: PcMap,
    /// Bumped on every flush; chain links from another generation are dead.
    pub generation: u64,
    /// Statistics.
    pub lookups: u64,
    pub misses: u64,
    pub flushes: u64,
    /// Per-PC hot-block profile (observability layer); `Some` only when
    /// profiling is enabled. Block counters are folded in here whenever a
    /// translation dies (replace/flush) and at harvest time, so churn at
    /// a PC survives the blocks themselves.
    pub prof: Option<Box<ProfileTable>>,
    /// Native x86-64 code for this cache's blocks (`--backend native`).
    /// Lazily populated; invalidated by generation stamping, so `flush`
    /// needs no extra bookkeeping here.
    #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
    pub native: super::codegen::NativeCache,
    /// Shared warm-start seed (fleet mode): consulted on lookup miss to
    /// materialise a block instead of retranslating. Dropped by `flush` —
    /// whatever invalidated the cache (fence.i, satp, model switch) also
    /// invalidates the premise the seed was built under.
    pub seed: Option<Arc<CodeSeed>>,
    /// Lookup misses satisfied from the seed (no translation performed).
    pub seed_hits: u64,
}

/// Compose the lookup key. Sv39 virtual addresses are canonical (bits
/// 63..39 equal bit 38), so the top two bits are redundant and can carry
/// the privilege mode.
#[inline]
pub fn cache_key(pc: u64, prv: u8) -> u64 {
    (pc & !(0b11 << 62)) | ((prv as u64) << 62)
}

impl CodeCache {
    pub fn new() -> CodeCache {
        CodeCache {
            blocks: Vec::with_capacity(1024),
            map: PcMap::default(),
            generation: 0,
            lookups: 0,
            misses: 0,
            flushes: 0,
            prof: None,
            #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
            native: super::codegen::NativeCache::new(),
            seed: None,
            seed_hits: 0,
        }
    }

    /// Install a shared warm-start seed. The caller is responsible for the
    /// stamp check (pipeline model + line shift) — see
    /// `ShardCore::install_code_seed`.
    pub fn set_seed(&mut self, seed: Arc<CodeSeed>) {
        self.seed = Some(seed);
    }

    /// Contribute every live translation of this cache to a warm-start
    /// seed (first writer wins on key collisions across caches).
    pub fn fold_into_seed(&self, seed: &mut CodeSeed) {
        for (&key, &id) in &self.map {
            seed.add(key, &self.blocks[id as usize]);
        }
    }

    #[inline]
    pub fn get(&mut self, pc: u64, prv: u8) -> Option<BlockId> {
        self.lookups += 1;
        let key = cache_key(pc, prv);
        if let Some(&id) = self.map.get(&key) {
            return Some(id);
        }
        // Miss: materialise from the shared seed when it carries this key.
        // `misses` keeps meaning "translations this cache had to perform",
        // so a seeded entry counts as a seed hit instead.
        if let Some(seed) = self.seed.clone() {
            if let Some(sb) = seed.lookup(key) {
                self.seed_hits += 1;
                let block = sb.instantiate();
                return Some(self.insert(pc, prv, block));
            }
        }
        self.misses += 1;
        None
    }

    pub fn insert(&mut self, pc: u64, prv: u8, block: Block) -> BlockId {
        if let Some(p) = &mut self.prof {
            p.entry(block.start).compiles += 1;
        }
        let id = self.blocks.len() as BlockId;
        self.blocks.push(block);
        self.map.insert(cache_key(pc, prv), id);
        id
    }

    /// Replace an existing translation (cross-page stub mismatch).
    pub fn replace(&mut self, id: BlockId, block: Block) {
        if let Some(p) = &mut self.prof {
            fold_block(p, &self.blocks[id as usize], true);
            p.entry(block.start).compiles += 1;
        }
        self.blocks[id as usize] = block;
        #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
        self.native.invalidate(id);
    }

    /// Compile block `id` to native code if needed (generation-checked).
    /// `line_shift` is the current L0 D-cache line shift, baked into the
    /// emitted probes; `model_digest` the pipeline model's configuration
    /// digest (stamped so reconfigured models never reuse old code).
    #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
    pub fn ensure_native(&mut self, id: BlockId, line_shift: u32, model_digest: u64) {
        let block = &self.blocks[id as usize];
        self.native.ensure(
            self.generation,
            line_shift,
            model_digest,
            self.prof.is_some(),
            id,
            block,
        );
    }

    #[inline]
    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[id as usize]
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Flush all translations (fence.i, satp write, model switch §3.5).
    pub fn flush(&mut self) {
        if let Some(p) = &mut self.prof {
            for block in &self.blocks {
                fold_block(p, block, true);
            }
        }
        self.blocks.clear();
        self.map.clear();
        // The seed was built under pre-flush conditions (guest code bytes,
        // address-space mapping, pipeline model); drop it with them.
        self.seed = None;
        self.generation += 1;
        self.flushes += 1;
    }

    /// Arm per-PC profiling on this cache (idempotent). The native cache
    /// picks the flag up through `ensure_native`'s profile stamp.
    pub fn enable_profile(&mut self) {
        if self.prof.is_none() {
            self.prof = Some(Box::default());
        }
    }

    #[inline]
    pub fn profiling(&self) -> bool {
        self.prof.is_some()
    }

    /// Harvest the per-PC profile: folds counters from all live blocks
    /// (without counting an invalidation — the blocks stay hot), returns
    /// the accumulated table, and re-arms an empty one.
    pub fn take_profile(&mut self) -> Option<ProfileTable> {
        let mut table = self.prof.take()?;
        for block in &self.blocks {
            fold_block(&mut table, block, false);
        }
        self.prof = Some(Box::default());
        Some(*table)
    }

    /// Store a chain link to an already-resolved target, stamped with the
    /// current generation (the eager installation the dispatch loop does
    /// right after a lookup/translation, so no PC re-hash is ever needed).
    #[inline]
    pub fn install_link(&self, from: BlockId, taken: bool, target: BlockId) {
        let b = self.block(from);
        let link = if taken { &b.chain_taken } else { &b.chain_seq };
        link.install(self.generation, target);
    }

    /// Follow a previously-established chain link. Links from a stale
    /// generation (installed before a flush) are never followed.
    #[inline]
    pub fn follow_chain(&self, from: BlockId, taken: bool) -> Option<BlockId> {
        let b = self.block(from);
        let link = if taken { &b.chain_taken } else { &b.chain_seq };
        link.follow(self.generation)
    }
}

impl Default for CodeCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Fold one block's profiling cells into the per-PC table, draining the
/// cells so repeated folds (harvest then flush) never double-count.
fn fold_block(table: &mut ProfileTable, block: &Block, invalidated: bool) {
    let s = table.entry(block.start);
    s.end = block.end;
    s.exec += block.prof.exec.take();
    s.cycles += block.prof.cycles.take();
    s.chain_hits += block.prof.chain_hits.take();
    s.chain_misses += block.prof.chain_misses.take();
    if invalidated {
        s.invalidations += 1;
    }
    if s.listing.is_empty() {
        for step in &block.steps {
            s.listing.push(format!("{:#x}: {}", block.start + step.pc_off as u64, step.op));
        }
        s.listing
            .push(format!("{:#x}: {}", block.start + block.term.pc_off as u64, block.term.op));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbt::compiler::translate;
    use crate::pipeline::SimpleModel;
    use crate::sys::Trap;

    fn trivial_block(pc: u64) -> Block {
        // "ret" at pc
        let bytes = {
            let mut a = crate::asm::Assembler::new(pc);
            a.ret();
            a.finish().bytes
        };
        let mut f = move |addr: u64| -> Result<u16, Trap> {
            let i = (addr - pc) as usize;
            Ok(u16::from_le_bytes([bytes[i], bytes[i + 1]]))
        };
        let mut m = SimpleModel;
        translate(&mut f, &mut m, pc, 6).unwrap()
    }

    #[test]
    fn insert_get() {
        let mut c = CodeCache::new();
        assert_eq!(c.get(0x8000_0000, 3), None);
        let id = c.insert(0x8000_0000, 3, trivial_block(0x8000_0000));
        assert_eq!(c.get(0x8000_0000, 3), Some(id));
        // Different privilege = different key.
        assert_eq!(c.get(0x8000_0000, 1), None);
        assert_eq!(c.misses, 2);
        assert_eq!(c.lookups, 3);
    }

    #[test]
    fn flush_invalidates_and_bumps_generation() {
        let mut c = CodeCache::new();
        c.insert(0x8000_0000, 3, trivial_block(0x8000_0000));
        let g = c.generation;
        c.flush();
        assert_eq!(c.get(0x8000_0000, 3), None);
        assert_eq!(c.generation, g + 1);
        assert!(c.is_empty());
    }

    #[test]
    fn chaining() {
        let mut c = CodeCache::new();
        let a = c.insert(0x1000, 3, trivial_block(0x1000));
        let b = c.insert(0x2000, 3, trivial_block(0x2000));
        assert_eq!(c.follow_chain(a, true), None);
        c.install_link(a, true, b);
        assert_eq!(c.follow_chain(a, true), Some(b));
        assert_eq!(c.follow_chain(a, false), None, "slots are independent");
        c.install_link(a, false, a);
        assert_eq!(c.follow_chain(a, false), Some(a), "self-links allowed");
    }

    #[test]
    fn key_privilege_separation() {
        assert_ne!(cache_key(0x1000, 0), cache_key(0x1000, 3));
        assert_eq!(cache_key(0x1000, 3), cache_key(0x1000, 3));
    }

    #[test]
    fn stale_generation_chain_links_are_never_followed() {
        // A link installed before a flush must be dead afterwards, even
        // when block ids are reused by post-flush translations at the very
        // same addresses.
        let mut c = CodeCache::new();
        let a = c.insert(0x1000, 3, trivial_block(0x1000));
        let b = c.insert(0x2000, 3, trivial_block(0x2000));
        c.install_link(a, true, b);
        assert_eq!(c.follow_chain(a, true), Some(b));
        c.flush();
        let a2 = c.insert(0x1000, 3, trivial_block(0x1000));
        let b2 = c.insert(0x2000, 3, trivial_block(0x2000));
        assert_eq!((a2, b2), (a, b), "ids are reused across the flush");
        assert_eq!(c.follow_chain(a2, true), None, "fresh blocks start unlinked");
        // Re-install under the new generation and it works again.
        c.install_link(a2, true, b2);
        assert_eq!(c.follow_chain(a2, true), Some(b2));
        // A link cell stamped with an old generation (simulating a cell
        // that somehow survived) is rejected by the generation check.
        let blk = c.block(a2);
        blk.chain_seq.install(c.generation - 1, b2);
        assert_eq!(c.follow_chain(a2, false), None, "stale generation rejected");
    }

    #[test]
    fn profile_table_tracks_churn_and_folds_counters() {
        let mut c = CodeCache::new();
        assert!(!c.profiling());
        c.enable_profile();
        let id = c.insert(0x1000, 3, trivial_block(0x1000));
        c.block(id).prof.exec.set(7);
        c.block(id).prof.cycles.set(21);
        // Replace folds the dying block, counting an invalidation and the
        // retranslation's compile.
        c.replace(id, trivial_block(0x1000));
        c.block(id).prof.exec.set(2);
        c.flush();
        let table = c.take_profile().unwrap();
        let s = &table.map[&0x1000];
        assert_eq!(s.compiles, 2);
        assert_eq!(s.invalidations, 2, "one from replace, one from flush");
        assert_eq!(s.exec, 9, "counters from both generations folded");
        assert_eq!(s.cycles, 21);
        assert!(s.end > 0x1000, "end PC captured from the translation");
        assert!(!s.listing.is_empty(), "disassembly captured at fold time");
        assert!(c.profiling(), "take_profile re-arms an empty table");
        assert!(c.take_profile().unwrap().map.is_empty());
    }

    #[test]
    fn disabled_profiling_keeps_hooks_inert() {
        let mut c = CodeCache::new();
        let id = c.insert(0x1000, 3, trivial_block(0x1000));
        c.replace(id, trivial_block(0x1000));
        c.flush();
        assert!(c.take_profile().is_none());
    }

    #[test]
    fn seed_materializes_blocks_without_counting_a_miss() {
        let mut warm = CodeCache::new();
        let warm_id = warm.insert(0x1000, 3, trivial_block(0x1000));
        let mut seed = CodeSeed::new("simple", 0, 6);
        warm.fold_into_seed(&mut seed);
        assert_eq!(seed.len(), 1);

        let mut cold = CodeCache::new();
        cold.set_seed(Arc::new(seed));
        let got = cold.get(0x1000, 3).expect("seed satisfies the miss");
        assert_eq!(cold.seed_hits, 1);
        assert_eq!(cold.misses, 0, "a seeded entry is not a translation miss");
        // Identical translation payload, fresh per-instance mutable state.
        let b = cold.block(got);
        let w = warm.block(warm_id);
        assert_eq!((b.start, b.end), (w.start, w.end));
        assert_eq!(b.steps.len(), w.steps.len());
        assert!(b.chain_taken.is_empty() && b.chain_seq.is_empty());
        // Unseeded keys still miss normally.
        assert_eq!(cold.get(0x2000, 3), None);
        assert_eq!(cold.misses, 1);
        // Later lookups hit the materialised copy, not the seed again.
        assert_eq!(cold.get(0x1000, 3), Some(got));
        assert_eq!(cold.seed_hits, 1);
    }

    #[test]
    fn flush_drops_the_seed() {
        let mut warm = CodeCache::new();
        warm.insert(0x1000, 3, trivial_block(0x1000));
        let mut seed = CodeSeed::new("simple", 0, 6);
        warm.fold_into_seed(&mut seed);
        let mut c = CodeCache::new();
        c.set_seed(Arc::new(seed));
        assert!(c.get(0x1000, 3).is_some());
        c.flush();
        assert!(c.seed.is_none(), "fence.i/satp invalidation also kills the seed");
        assert_eq!(c.get(0x1000, 3), None);
    }
}
