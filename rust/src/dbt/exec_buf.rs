//! W^X executable code buffer for the native DBT backend.
//!
//! The buffer is mmap'd RW for emission and patching, then remapped RX for
//! execution (`make_exec`), and back (`make_writable`) when a chain patch
//! or new block needs to touch it. Whole-buffer mprotect keeps the
//! protocol simple; emission is rare relative to execution.
//!
//! Only compiled on x86-64 Linux — the only host the native backend
//! supports — so the raw mmap externs never reach other targets.

use std::ffi::c_void;

// std already links libc; declare the three calls we need rather than
// adding a crate dependency.
extern "C" {
    fn mmap(
        addr: *mut c_void,
        len: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut c_void;
    fn mprotect(addr: *mut c_void, len: usize, prot: i32) -> i32;
    fn munmap(addr: *mut c_void, len: usize) -> i32;
}

const PROT_READ: i32 = 1;
const PROT_WRITE: i32 = 2;
const PROT_EXEC: i32 = 4;
const MAP_PRIVATE: i32 = 2;
const MAP_ANONYMOUS: i32 = 0x20;

/// An mmap'd code buffer with a bump allocator and a W^X protection
/// toggle.
pub struct ExecBuf {
    base: *mut u8,
    cap: usize,
    len: usize,
    exec: bool,
}

// The buffer is owned by exactly one `ShardCore` at a time; raw pointers
// just make the auto-trait opt-out conservative. Moving it across threads
// (the sharded engine moves cores into workers) is fine.
unsafe impl Send for ExecBuf {}

impl ExecBuf {
    /// Map a fresh RW buffer of `cap` bytes. Returns `None` if mmap fails.
    pub fn new(cap: usize) -> Option<ExecBuf> {
        let base = unsafe {
            mmap(
                std::ptr::null_mut(),
                cap,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if base as isize == -1 || base.is_null() {
            return None;
        }
        Some(ExecBuf { base: base as *mut u8, cap, len: 0, exec: false })
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn remaining(&self) -> usize {
        self.cap - self.len
    }

    /// Absolute address of buffer offset `off`.
    pub fn addr(&self, off: u32) -> u64 {
        debug_assert!((off as usize) <= self.len);
        self.base as u64 + off as u64
    }

    /// Remap RX. Idempotent.
    pub fn make_exec(&mut self) {
        if !self.exec {
            let r = unsafe { mprotect(self.base as *mut c_void, self.cap, PROT_READ | PROT_EXEC) };
            assert_eq!(r, 0, "mprotect RX failed");
            self.exec = true;
        }
    }

    /// Remap RW. Idempotent.
    pub fn make_writable(&mut self) {
        if self.exec {
            let r = unsafe { mprotect(self.base as *mut c_void, self.cap, PROT_READ | PROT_WRITE) };
            assert_eq!(r, 0, "mprotect RW failed");
            self.exec = false;
        }
    }

    /// Append `code`, returning its start offset, or `None` if it does not
    /// fit. The buffer must be writable.
    pub fn append(&mut self, code: &[u8]) -> Option<u32> {
        debug_assert!(!self.exec, "append on executable buffer");
        if code.len() > self.remaining() {
            return None;
        }
        let off = self.len;
        unsafe {
            std::ptr::copy_nonoverlapping(code.as_ptr(), self.base.add(off), code.len());
        }
        self.len += code.len();
        Some(off as u32)
    }

    /// Overwrite 4 bytes at `off` (rel32 chain patching). The buffer must
    /// be writable.
    pub fn write4(&mut self, off: u32, bytes: [u8; 4]) {
        debug_assert!(!self.exec, "patch on executable buffer");
        assert!((off as usize) + 4 <= self.len);
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), self.base.add(off as usize), 4);
        }
    }

    /// Read back `len` bytes at `off` (for `--dump-native`).
    pub fn read(&self, off: u32, len: usize) -> Vec<u8> {
        assert!((off as usize) + len <= self.len);
        let mut out = vec![0u8; len];
        unsafe {
            std::ptr::copy_nonoverlapping(self.base.add(off as usize), out.as_mut_ptr(), len);
        }
        out
    }

    /// Discard all emitted code: the bump pointer rewinds to zero and the
    /// buffer becomes writable. Previously handed-out offsets are dead.
    pub fn reset(&mut self) {
        self.make_writable();
        self.len = 0;
    }
}

impl Drop for ExecBuf {
    fn drop(&mut self) {
        unsafe {
            munmap(self.base as *mut c_void, self.cap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_protect_execute_roundtrip() {
        let mut buf = ExecBuf::new(4096).expect("mmap");
        // mov rax, 42; ret
        let code = [0x48, 0xC7, 0xC0, 0x2A, 0x00, 0x00, 0x00, 0xC3];
        let off = buf.append(&code).unwrap();
        buf.make_exec();
        let f: extern "sysv64" fn() -> u64 =
            unsafe { std::mem::transmute(buf.addr(off) as *const u8) };
        assert_eq!(f(), 42);
        // Patch the imm32 to 7 and re-run.
        buf.make_writable();
        buf.write4(off + 3, 7u32.to_le_bytes());
        buf.make_exec();
        assert_eq!(f(), 7);
    }

    #[test]
    fn exhaustion_and_reset() {
        let mut buf = ExecBuf::new(4096).expect("mmap");
        let chunk = [0x90u8; 1024]; // nops
        assert!(buf.append(&chunk).is_some());
        assert!(buf.append(&chunk).is_some());
        assert!(buf.append(&chunk).is_some());
        assert!(buf.append(&chunk).is_some());
        assert!(buf.append(&chunk).is_none(), "fifth KiB must not fit");
        buf.reset();
        assert_eq!(buf.len(), 0);
        assert!(buf.append(&chunk).is_some());
    }
}
