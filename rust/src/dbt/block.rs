//! Translated basic blocks: the cached unit of the DBT engine.
//!
//! In the original R2VM the translator emits AMD64 machine code; here each
//! basic block is translated once into a *micro-op trace* — pre-decoded
//! instructions with their pipeline-model cycle costs baked in at
//! translation time (§3.2: "models pipeline behaviours during DBT code
//! generation ... therefore requires no explicit code to be executed in
//! runtime") — and executed by a threaded dispatch loop. The structural
//! properties the paper measures (translate-once, per-hart code caches,
//! block chaining, cross-page stubs) are preserved; see DESIGN.md §3.

use crate::isa::op::Op;
use std::cell::Cell;

/// Index of a block within its (per-hart) code cache arena.
pub type BlockId = u32;

/// A translated non-terminator instruction.
#[derive(Debug, Clone, Copy)]
pub struct Step {
    pub op: Op,
    /// Offset of this instruction from the block start (bytes).
    pub pc_off: u16,
    /// Encoded length (2 or 4).
    pub len: u8,
    /// Cycles charged when this step retires (pipeline hooks, baked in at
    /// translation time).
    pub cycles: u32,
    /// Is this a synchronisation point (§3.3.2: memory or control-register
    /// operation)? The engine yields pending cycles *before* executing it.
    pub sync: bool,
}

/// How a block ends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TermKind {
    /// Conditional branch; taken target = term pc + imm.
    Branch,
    /// Direct jump (JAL) — target known at translation time.
    Jump { target: u64 },
    /// Indirect jump (JALR) — target known only at runtime.
    IndirectJump,
    /// Instruction that must be executed then falls through with a
    /// mandatory return to the engine (system instructions, fence.i, ...).
    Fallthrough,
}

/// The translated terminator.
#[derive(Debug, Clone, Copy)]
pub struct Term {
    pub op: Op,
    pub pc_off: u16,
    pub len: u8,
    pub kind: TermKind,
    /// Cycles when not taken / sequential.
    pub cycles_nt: u32,
    /// Cycles when taken (branch/jump).
    pub cycles_taken: u32,
    pub sync: bool,
}

/// Cross-page guard (§3.1): a 4-byte instruction spanning two pages is
/// translated against the bytes seen at translation time; at each entry the
/// stub re-reads the two bytes on the second page and retranslates on
/// mismatch.
#[derive(Debug, Clone, Copy)]
pub struct CrossPageStub {
    /// Virtual address of the second-page halfword.
    pub vaddr: u64,
    /// Halfword observed at translation time.
    pub expected: u16,
}

/// A translated basic block.
pub struct Block {
    /// Guest virtual address of the first instruction.
    pub start: u64,
    /// Virtual address one past the last instruction byte.
    pub end: u64,
    pub steps: Vec<Step>,
    pub term: Term,
    /// Virtual addresses whose L0 I-cache lines must be checked on entry
    /// (block start + each cache-line crossing, §3.4.2: one access per
    /// 16-32 instructions at 64-byte lines).
    pub icache_checks: Vec<u64>,
    pub cross_page: Option<CrossPageStub>,
    /// Block chaining (§3.1): resolved successor block ids, validated
    /// against the code-cache generation. `u32::MAX` = unresolved.
    pub chain_taken: Cell<BlockId>,
    pub chain_seq: Cell<BlockId>,
}

pub const NO_CHAIN: BlockId = u32::MAX;

impl Block {
    /// PC of the terminator instruction.
    #[inline]
    pub fn term_pc(&self) -> u64 {
        self.start + self.term.pc_off as u64
    }

    /// Sequential successor address (past the terminator).
    #[inline]
    pub fn seq_target(&self) -> u64 {
        self.term_pc() + self.term.len as u64
    }

    /// Taken target for a conditional branch terminator.
    #[inline]
    pub fn taken_target(&self) -> u64 {
        match self.term.op {
            Op::Branch { imm, .. } => self.term_pc().wrapping_add(imm as i64 as u64),
            _ => match self.term.kind {
                TermKind::Jump { target } => target,
                _ => unreachable!("taken_target on non-branch/jump"),
            },
        }
    }

    /// Total retired instructions if the block runs to completion.
    #[inline]
    pub fn inst_count(&self) -> u64 {
        self.steps.len() as u64 + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::op::{BrCond, Op};

    fn mk_block() -> Block {
        Block {
            start: 0x8000_0000,
            end: 0x8000_000c,
            steps: vec![Step {
                op: Op::AluImm {
                    op: crate::isa::AluOp::Add,
                    word: false,
                    rd: 1,
                    rs1: 1,
                    imm: 1,
                },
                pc_off: 0,
                len: 4,
                cycles: 1,
                sync: false,
            }],
            term: Term {
                op: Op::Branch { cond: BrCond::Ne, rs1: 1, rs2: 0, imm: -4 },
                pc_off: 4,
                len: 4,
                kind: TermKind::Branch,
                cycles_nt: 1,
                cycles_taken: 3,
                sync: false,
            },
            icache_checks: vec![0x8000_0000],
            cross_page: None,
            chain_taken: Cell::new(NO_CHAIN),
            chain_seq: Cell::new(NO_CHAIN),
        }
    }

    #[test]
    fn targets() {
        let b = mk_block();
        assert_eq!(b.term_pc(), 0x8000_0004);
        assert_eq!(b.seq_target(), 0x8000_0008);
        assert_eq!(b.taken_target(), 0x8000_0000);
        assert_eq!(b.inst_count(), 2);
    }
}
