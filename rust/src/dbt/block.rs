//! Translated basic blocks: the cached unit of the DBT engine.
//!
//! In the original R2VM the translator emits AMD64 machine code; here each
//! basic block is translated once into a *micro-op trace* — pre-decoded
//! instructions with their pipeline-model cycle costs baked in at
//! translation time (§3.2: "models pipeline behaviours during DBT code
//! generation ... therefore requires no explicit code to be executed in
//! runtime") — and executed by a threaded dispatch loop. The structural
//! properties the paper measures (translate-once, per-hart code caches,
//! block chaining, cross-page stubs) are preserved; see DESIGN.md §3.

use crate::isa::op::Op;
use crate::pipeline::InstDesc;
use std::cell::Cell;

/// Index of a block within its (per-hart) code cache arena.
pub type BlockId = u32;

/// A block-chaining link (§3.1): the successor block id packed with the
/// code-cache generation at install time. Following validates the
/// generation, so a link installed before a cache flush is dead the moment
/// the flush bumps the generation — block ids are reused across flushes
/// and a naked id could otherwise name an unrelated translation.
///
/// Layout: `(generation & 0xffff_ffff) << 32 | id`; `u64::MAX` = empty.
/// (Truncating the generation to 32 bits is safe: a collision needs 2^32
/// flushes between install and follow with the link cell itself surviving,
/// and flushes destroy every block, link cells included.)
#[derive(Debug)]
pub struct ChainLink(Cell<u64>);

const NO_LINK: u64 = u64::MAX;

impl ChainLink {
    pub fn empty() -> ChainLink {
        ChainLink(Cell::new(NO_LINK))
    }

    /// Target block id, if a link was installed in generation `gen`.
    #[inline(always)]
    pub fn follow(&self, gen: u64) -> Option<BlockId> {
        let v = self.0.get();
        if v != NO_LINK && (v >> 32) == (gen & 0xffff_ffff) {
            Some(v as u32)
        } else {
            None
        }
    }

    /// Install a link to `id`, stamped with generation `gen`.
    #[inline]
    pub fn install(&self, gen: u64, id: BlockId) {
        self.0.set(((gen & 0xffff_ffff) << 32) | id as u64);
    }

    pub fn clear(&self) {
        self.0.set(NO_LINK);
    }

    pub fn is_empty(&self) -> bool {
        self.0.get() == NO_LINK
    }
}

/// A translated non-terminator instruction.
#[derive(Debug, Clone, Copy)]
pub struct Step {
    pub op: Op,
    /// Offset of this instruction from the block start (bytes).
    pub pc_off: u16,
    /// Encoded length (2 or 4).
    pub len: u8,
    /// Cycles charged when this step retires (pipeline hooks, baked in at
    /// translation time).
    pub cycles: u32,
    /// Is this a synchronisation point (§3.3.2: memory or control-register
    /// operation)? The engine yields pending cycles *before* executing it.
    pub sync: bool,
}

/// How a block ends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TermKind {
    /// Conditional branch; taken target = term pc + imm.
    Branch,
    /// Direct jump (JAL) — target known at translation time.
    Jump { target: u64 },
    /// Indirect jump (JALR) — target known only at runtime.
    IndirectJump,
    /// Instruction that must be executed then falls through with a
    /// mandatory return to the engine (system instructions, fence.i, ...).
    Fallthrough,
}

/// The translated terminator.
#[derive(Debug, Clone, Copy)]
pub struct Term {
    pub op: Op,
    pub pc_off: u16,
    pub len: u8,
    pub kind: TermKind,
    /// Cycles when not taken / sequential.
    pub cycles_nt: u32,
    /// Cycles when taken (branch/jump).
    pub cycles_taken: u32,
    pub sync: bool,
}

/// Cross-page guard (§3.1): a 4-byte instruction spanning two pages is
/// translated against the bytes seen at translation time; at each entry the
/// stub re-reads the two bytes on the second page and retranslates on
/// mismatch.
#[derive(Debug, Clone, Copy)]
pub struct CrossPageStub {
    /// Virtual address of the second-page halfword.
    pub vaddr: u64,
    /// Halfword observed at translation time.
    pub expected: u16,
}

/// Per-block profiling counters (observability layer, DESIGN.md §12).
/// `Cell`s because the dispatch loop holds shared borrows of blocks; only
/// bumped when profiling is enabled, so the disabled hot path never
/// touches them. Counters are folded into the per-PC
/// `obs::ProfileTable` when the block is invalidated or harvested.
#[derive(Debug, Default)]
pub struct BlockProf {
    /// Dispatch entries (bumped in `enter_block` for both backends, so
    /// microop and native attribute identical execution counts).
    pub exec: Cell<u64>,
    /// Model cycles charged while executing this block (per-step retire
    /// for microop, baked per-segment increment for native, terminator
    /// cycles from the shared retire path).
    pub cycles: Cell<u64>,
    /// Entries that arrived via a validated chain link.
    pub chain_hits: Cell<u64>,
    /// Entries that paid the hash-lookup slow path.
    pub chain_misses: Cell<u64>,
}

/// A translated basic block.
pub struct Block {
    /// Guest virtual address of the first instruction.
    pub start: u64,
    /// Virtual address one past the last instruction byte.
    pub end: u64,
    pub steps: Vec<Step>,
    pub term: Term,
    /// Virtual addresses whose L0 I-cache lines must be checked on entry
    /// (block start + each cache-line crossing, §3.4.2: one access per
    /// 16-32 instructions at 64-byte lines).
    pub icache_checks: Vec<u64>,
    pub cross_page: Option<CrossPageStub>,
    /// Block chaining (§3.1): generation-validated successor links,
    /// followed directly by the dispatch loop without re-hashing the PC.
    /// `chain_taken` holds the taken-branch / jump / indirect-last-target
    /// successor, `chain_seq` the sequential one.
    pub chain_taken: ChainLink,
    pub chain_seq: ChainLink,
    /// Dynamic-tier descriptor trace (DESIGN.md §14): one [`InstDesc`]
    /// per step plus one for the terminator (always `steps.len() + 1`
    /// long), recorded only when the block was translated for a
    /// dynamic-tier pipeline model; empty for static models.
    pub dtrace: Vec<InstDesc>,
    /// Profiling counters; untouched (and never read) unless profiling
    /// is enabled.
    pub prof: BlockProf,
}

pub const NO_CHAIN: BlockId = u32::MAX;

impl Block {
    /// PC of the terminator instruction.
    #[inline]
    pub fn term_pc(&self) -> u64 {
        self.start + self.term.pc_off as u64
    }

    /// Sequential successor address (past the terminator).
    #[inline]
    pub fn seq_target(&self) -> u64 {
        self.term_pc() + self.term.len as u64
    }

    /// Taken target for a conditional branch terminator.
    #[inline]
    pub fn taken_target(&self) -> u64 {
        match self.term.op {
            Op::Branch { imm, .. } => self.term_pc().wrapping_add(imm as i64 as u64),
            _ => match self.term.kind {
                TermKind::Jump { target } => target,
                _ => unreachable!("taken_target on non-branch/jump"),
            },
        }
    }

    /// Total retired instructions if the block runs to completion.
    #[inline]
    pub fn inst_count(&self) -> u64 {
        self.steps.len() as u64 + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::op::{BrCond, Op};

    fn mk_block() -> Block {
        Block {
            start: 0x8000_0000,
            end: 0x8000_000c,
            steps: vec![Step {
                op: Op::AluImm {
                    op: crate::isa::AluOp::Add,
                    word: false,
                    rd: 1,
                    rs1: 1,
                    imm: 1,
                },
                pc_off: 0,
                len: 4,
                cycles: 1,
                sync: false,
            }],
            term: Term {
                op: Op::Branch { cond: BrCond::Ne, rs1: 1, rs2: 0, imm: -4 },
                pc_off: 4,
                len: 4,
                kind: TermKind::Branch,
                cycles_nt: 1,
                cycles_taken: 3,
                sync: false,
            },
            icache_checks: vec![0x8000_0000],
            cross_page: None,
            chain_taken: ChainLink::empty(),
            chain_seq: ChainLink::empty(),
            dtrace: Vec::new(),
            prof: BlockProf::default(),
        }
    }

    #[test]
    fn targets() {
        let b = mk_block();
        assert_eq!(b.term_pc(), 0x8000_0004);
        assert_eq!(b.seq_target(), 0x8000_0008);
        assert_eq!(b.taken_target(), 0x8000_0000);
        assert_eq!(b.inst_count(), 2);
    }

    #[test]
    fn chain_link_generation_validation() {
        let link = ChainLink::empty();
        assert!(link.is_empty());
        assert_eq!(link.follow(0), None);
        link.install(3, 17);
        assert_eq!(link.follow(3), Some(17), "same generation follows");
        // A stale-generation link must never be followed after a flush
        // bumps the cache generation.
        assert_eq!(link.follow(4), None, "newer generation rejects");
        assert_eq!(link.follow(2), None, "older generation rejects");
        link.clear();
        assert!(link.is_empty());
        assert_eq!(link.follow(3), None);
        // id 0 in generation 0 is a valid link, not the empty sentinel.
        link.install(0, 0);
        assert_eq!(link.follow(0), Some(0));
    }
}
