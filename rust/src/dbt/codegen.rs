//! Native x86-64 block codegen for the DBT.
//!
//! Lowers translated blocks to host machine code in a W^X [`ExecBuf`].
//! The unit of native execution is a *segment*: an optional leading
//! synchronising Load/Store followed by a run of non-synchronising
//! lowerable steps (ALU, LUI/AUIPC, MUL via helper, FENCE). Segments never
//! include the terminator; terminators of kind Branch / Jump /
//! IndirectJump get separate native code. All scheduler, yield, chaining,
//! interrupt and trap bookkeeping stays in Rust, which is what makes the
//! backend bit-identical to the micro-op interpreter by construction: the
//! emitted code only replicates the exact per-step arithmetic and the L0
//! hit path (including its counter updates), and calls back into the
//! `#[cold]` Rust continuations for everything else.
//!
//! Exit protocol (return value of a native call):
//!   * [`RC_SEG_DONE`]  — segment retired completely;
//!   * [`RC_TRAP`]      — the segment's leading memory op trapped
//!     (cause/tval are in the [`NativeCtx`]); only the first step of a
//!     segment can trap, so no step index is needed;
//!   * [`RC_TERM`]      — terminator executed (taken / jump_target in ctx);
//!   * `(id << 8) | RC_CHAINED` — terminator executed and a patched chain
//!     `jmp` landed in block `id`'s identify thunk. Handled identically to
//!     `RC_TERM` by the caller: the thunk exists so that a chained jump
//!     *exits to Rust* instead of staying in host code, keeping the
//!     deterministic scheduler in charge.
//!
//! Trampoline ABI: `extern "sysv64" fn(ctx: *mut NativeCtx, body: u64)`.
//! A shared prologue at buffer offset 0 pushes rbx/rbp/r12/r13/r14, loads
//! `rbx = ctx`, `rbp = ctx.regs`, and jumps to `body`. Five pushes leave
//! rsp ≡ 0 (mod 16) in the body, so helper `call`s see a correctly
//! aligned SysV stack. Every exit point inlines the matching epilogue.

use super::block::{Block, TermKind};
use super::exec_buf::ExecBuf;
use super::x86::{self, AluKind, Asm, Reg, ShiftKind};
use crate::isa::op::{AluOp, BrCond, MemWidth, MulOp, Op};
use crate::mem::l0::L0_ENTRIES;

// ---------------------------------------------------------------------------
// Context handed to native code (rbx points here for the whole call).
// ---------------------------------------------------------------------------

/// Runtime context for a native call. Field order is ABI: the emitted code
/// addresses fields by the `OFF_*` byte offsets below (verified by test).
#[repr(C)]
pub struct NativeCtx {
    /// Guest integer register file (`hart.regs`); rbp caches this.
    pub regs: *mut u64,
    /// L0 D-cache packed tag array.
    pub d_tags: *const u64,
    /// L0 D-cache `vaddr ^ paddr` array.
    pub d_xors: *const u64,
    /// L0 D-cache `accesses` counter (bumped inline on hits only; the
    /// slow-path helper re-runs the Rust lookup which does its own bump).
    pub d_acc: *mut u64,
    /// `host_base - DRAM_BASE`: add to a paddr to get the host address.
    pub dram_bias: u64,
    /// `System::active_reservations` (stores with live reservations take
    /// the slow path so LR/SC bookkeeping stays in Rust).
    pub resv: *const u32,
    /// Out: indirect-jump target (Jalr terminators).
    pub jump_target: u64,
    /// Out: branch outcome (0 = not taken).
    pub taken: u64,
    /// `fiber::native::helper_read` as a raw fn address.
    pub helper_read: usize,
    /// `fiber::native::helper_write`.
    pub helper_write: usize,
    /// `fiber::native::helper_mul`.
    pub helper_mul: usize,
    /// Out: trap cause (valid when the call returns [`RC_TRAP`]).
    pub trap_cause: u64,
    /// Out: trap tval.
    pub trap_tval: u64,
    /// The `Hart`, for helper re-entry (opaque to emitted code).
    pub hart: *mut u8,
    /// The `System`, for helper re-entry (opaque to emitted code).
    pub sys: *mut u8,
    /// Profiling: points at the current block's `BlockProf::cycles` cell,
    /// or null when profiling is off. Profiled segments bake
    /// `*prof_cycles += seg_cycles` on the fully-retired exit only
    /// (RC_SEG_DONE) — a trapped segment retires nothing, matching the
    /// microop engine's per-retired-step charging exactly.
    pub prof_cycles: *mut u64,
}

pub const OFF_REGS: i32 = 0x00;
pub const OFF_DTAGS: i32 = 0x08;
pub const OFF_DXORS: i32 = 0x10;
pub const OFF_DACC: i32 = 0x18;
pub const OFF_BIAS: i32 = 0x20;
pub const OFF_RESV: i32 = 0x28;
pub const OFF_JTARGET: i32 = 0x30;
pub const OFF_TAKEN: i32 = 0x38;
pub const OFF_HREAD: i32 = 0x40;
pub const OFF_HWRITE: i32 = 0x48;
pub const OFF_HMUL: i32 = 0x50;
pub const OFF_TCAUSE: i32 = 0x58;
pub const OFF_TTVAL: i32 = 0x60;
pub const OFF_PROF: i32 = 0x78;

/// Segment retired completely.
pub const RC_SEG_DONE: u64 = 0;
/// Terminator executed.
pub const RC_TERM: u64 = 1;
/// Leading memory op trapped.
pub const RC_TRAP: u64 = 2;
/// Low byte of a chained exit; bits 8.. carry the successor block id.
pub const RC_CHAINED: u64 = 3;

// ---------------------------------------------------------------------------
// Helper-call argument packing (shared with fiber::native's decoders).
// ---------------------------------------------------------------------------

pub fn pack_mem(width: MemWidth, signed: bool) -> u32 {
    width_code(width) | (signed as u32) << 2
}

pub fn unpack_mem(packed: u32) -> (MemWidth, bool) {
    (width_of(packed & 3), packed & 4 != 0)
}

fn width_code(width: MemWidth) -> u32 {
    match width {
        MemWidth::B => 0,
        MemWidth::H => 1,
        MemWidth::W => 2,
        MemWidth::D => 3,
    }
}

fn width_of(code: u32) -> MemWidth {
    match code {
        0 => MemWidth::B,
        1 => MemWidth::H,
        2 => MemWidth::W,
        _ => MemWidth::D,
    }
}

pub fn pack_mul(op: MulOp, word: bool) -> u32 {
    let c = match op {
        MulOp::Mul => 0,
        MulOp::Mulh => 1,
        MulOp::Mulhsu => 2,
        MulOp::Mulhu => 3,
        MulOp::Div => 4,
        MulOp::Divu => 5,
        MulOp::Rem => 6,
        MulOp::Remu => 7,
    };
    c | (word as u32) << 3
}

pub fn unpack_mul(packed: u32) -> (MulOp, bool) {
    let op = match packed & 7 {
        0 => MulOp::Mul,
        1 => MulOp::Mulh,
        2 => MulOp::Mulhsu,
        3 => MulOp::Mulhu,
        4 => MulOp::Div,
        5 => MulOp::Divu,
        6 => MulOp::Rem,
        _ => MulOp::Remu,
    };
    (op, packed & 8 != 0)
}

// ---------------------------------------------------------------------------
// Native block metadata
// ---------------------------------------------------------------------------

/// One native segment: steps `[first, end)` of the block.
#[derive(Clone, Copy)]
pub struct NativeSeg {
    /// One past the last step index covered.
    pub end: u16,
    /// Retired instruction count (`end - first`).
    pub count: u16,
    /// Buffer offset of the segment entry.
    pub entry: u32,
    /// Sum of the covered steps' model cycles.
    pub cycles: u64,
}

/// Compiled form of one translated block.
pub struct NativeBlock {
    pub segs: Vec<NativeSeg>,
    /// Per step index: index into `segs` of the segment *starting* there,
    /// or `u16::MAX`.
    pub seg_start: Box<[u16]>,
    /// Buffer offset of the terminator code, if the terminator lowers.
    pub term_entry: Option<u32>,
    /// Buffer offset of the identify thunk (chain patches land here).
    thunk: u32,
    /// rel32 field offset of the taken-edge chain slot.
    slot_taken: Option<u32>,
    /// rel32 field offset of the sequential-edge chain slot.
    slot_seq: Option<u32>,
}

enum NativeState {
    NotCompiled,
    /// Does not fit even in an empty buffer, or contains nothing to lower.
    Failed,
    Ready(NativeBlock),
}

/// Default per-core code buffer capacity.
const DEFAULT_CAPACITY: usize = 4 << 20;

/// Per-core native code cache, owned by [`crate::dbt::CodeCache`].
///
/// Invalidation is generation-stamped and lazy: `ensure` compares the
/// owning code cache's generation (and the current L0 line shift, which is
/// baked into emitted probes) and discards everything on mismatch — this
/// single rule covers `fence.i`, `sfence.vma`, SIMCTRL reconfiguration and
/// engine switches, because all of those flush the translation cache and
/// bump its generation. Buffer exhaustion resets only the native side
/// (architecturally invisible: patched jumps merely mirror `ChainLink`s
/// that Rust still consults).
pub struct NativeCache {
    buf: Option<ExecBuf>,
    capacity: usize,
    gen: u64,
    line_shift: u32,
    /// Pipeline-model configuration digest stamped like `gen`: two
    /// differently-parameterised models must never share native code
    /// (their baked cycle counts / descriptor interpretation differ).
    model_digest: u64,
    blocks: Vec<NativeState>,
    /// Whether emitted code carries the per-block profile increment.
    /// Stamped like `gen`/`line_shift`: a mismatch in `ensure` discards
    /// everything, so profiled and unprofiled code never mix — and with
    /// profiling off the emitted bytes are identical to a build without
    /// the profiler.
    profile: bool,
    /// Dump emitted code for the block containing this guest PC.
    pub dump_pc: Option<u64>,
    /// Stats (tests assert on these; also surfaced by `--dump-native`).
    pub compiles: u64,
    pub patches: u64,
    pub resets: u64,
    pub exhaustions: u64,
}

impl Default for NativeCache {
    fn default() -> Self {
        NativeCache::new()
    }
}

impl NativeCache {
    pub fn new() -> NativeCache {
        NativeCache {
            buf: None,
            capacity: DEFAULT_CAPACITY,
            gen: 0,
            line_shift: 0,
            model_digest: 0,
            blocks: Vec::new(),
            profile: false,
            dump_pc: None,
            compiles: 0,
            patches: 0,
            resets: 0,
            exhaustions: 0,
        }
    }

    /// Shrink the code buffer (test hook for exhaustion coverage). Takes
    /// effect immediately; everything compiled so far is discarded.
    pub fn set_capacity(&mut self, bytes: usize) {
        self.capacity = bytes;
        self.buf = None;
        self.blocks.clear();
    }

    /// Discard all native code and re-emit the shared prologue.
    fn reset(&mut self) {
        self.resets += 1;
        for s in &mut self.blocks {
            *s = NativeState::NotCompiled;
        }
        let buf = match &mut self.buf {
            Some(b) => {
                b.reset();
                b
            }
            None => return,
        };
        let mut a = Asm::new();
        emit_prologue(&mut a);
        buf.append(&a.code).expect("prologue must fit");
    }

    /// Make sure block `id` has an up-to-date native compilation attempt.
    /// `gen` is the owning `CodeCache::generation`; `line_shift` the
    /// current L0 D-cache line shift; `model_digest` the pipeline model's
    /// configuration digest; `profile` whether emitted code must carry
    /// the per-block cycle increment.
    pub fn ensure(
        &mut self,
        gen: u64,
        line_shift: u32,
        model_digest: u64,
        profile: bool,
        id: u32,
        block: &Block,
    ) {
        if self.buf.is_none() {
            self.buf = ExecBuf::new(self.capacity);
            if self.buf.is_none() {
                return; // mmap failed: native stays unavailable
            }
            self.gen = gen;
            self.line_shift = line_shift;
            self.model_digest = model_digest;
            self.profile = profile;
            self.reset();
            self.resets = 0; // the initial prologue emit is not a reset
        }
        if self.gen != gen
            || self.line_shift != line_shift
            || self.model_digest != model_digest
            || self.profile != profile
        {
            self.gen = gen;
            self.line_shift = line_shift;
            self.model_digest = model_digest;
            self.profile = profile;
            self.reset();
        }
        if self.blocks.len() <= id as usize {
            self.blocks.resize_with(id as usize + 1, || NativeState::NotCompiled);
        }
        if matches!(self.blocks[id as usize], NativeState::NotCompiled) {
            self.blocks[id as usize] = self.compile(id, block);
        }
    }

    /// Forget block `id`'s native code (its translation was replaced in
    /// place — cross-page stub invalidation). Stale chain patches keep
    /// jumping to the *old* identify thunk, which still returns the same
    /// id; the Rust chain protocol re-validates, so this is benign.
    pub fn invalidate(&mut self, id: u32) {
        if let Some(s) = self.blocks.get_mut(id as usize) {
            *s = NativeState::NotCompiled;
        }
    }

    /// The compiled block, if ready.
    pub fn block(&self, id: u32) -> Option<&NativeBlock> {
        match self.blocks.get(id as usize) {
            Some(NativeState::Ready(nb)) => Some(nb),
            _ => None,
        }
    }

    /// The segment starting at step `si` of block `id`, if any.
    pub fn seg_at(&self, id: u32, si: usize) -> Option<NativeSeg> {
        let nb = self.block(id)?;
        match nb.seg_start.get(si) {
            Some(&s) if s != u16::MAX => Some(nb.segs[s as usize]),
            _ => None,
        }
    }

    /// The terminator entry of block `id`, if it lowered.
    pub fn term_at(&self, id: u32) -> Option<u32> {
        self.block(id)?.term_entry
    }

    /// Mirror a `ChainLink` install as a patched direct `jmp`: the edge
    /// slot of `from` is redirected to `to`'s identify thunk. Skipped
    /// silently when either side has no native code — the Rust protocol
    /// alone then drives the edge.
    pub fn patch_link(&mut self, from: u32, taken: bool, to: u32) {
        let slot = match self.block(from) {
            Some(nb) => {
                if taken {
                    nb.slot_taken
                } else {
                    nb.slot_seq
                }
            }
            None => None,
        };
        let (slot, thunk) = match (slot, self.block(to).map(|nb| nb.thunk)) {
            (Some(s), Some(t)) => (s, t),
            _ => return,
        };
        let buf = self.buf.as_mut().expect("blocks exist, buffer exists");
        let rel = (thunk as i64 - (slot as i64 + 4)) as i32;
        buf.make_writable();
        buf.write4(slot, rel.to_le_bytes());
        self.patches += 1;
    }

    /// Execute native code at buffer offset `entry`.
    ///
    /// # Safety
    /// `ctx` must be fully populated with live pointers (regs, L0 arrays,
    /// helpers, hart, sys) and `entry` must be an offset handed out by
    /// `ensure` in the current generation.
    pub unsafe fn run(&mut self, entry: u32, ctx: *mut NativeCtx) -> u64 {
        let buf = self.buf.as_mut().expect("run without buffer");
        buf.make_exec();
        let f: extern "sysv64" fn(*mut NativeCtx, u64) -> u64 =
            std::mem::transmute(buf.addr(0) as *const u8);
        f(ctx, buf.addr(entry))
    }

    fn compile(&mut self, id: u32, block: &Block) -> NativeState {
        let plan = plan_block(block);
        if plan.segs.is_empty() && plan.term_kind.is_none() {
            return NativeState::Failed;
        }
        let mut a = Asm::new();
        let code = emit_block(&mut a, id, block, &plan, self.line_shift, self.profile);

        let buf = self.buf.as_mut().expect("ensure allocated the buffer");
        buf.make_writable();
        let base = match buf.append(&a.code) {
            Some(b) => b,
            None => {
                // Exhausted: drop all native code (Rust chaining state is
                // untouched) and retry once in the empty buffer.
                self.exhaustions += 1;
                self.reset();
                let buf = self.buf.as_mut().unwrap();
                match buf.append(&a.code) {
                    Some(b) => b,
                    None => return NativeState::Failed,
                }
            }
        };
        self.compiles += 1;

        let nb = NativeBlock {
            segs: code
                .segs
                .iter()
                .map(|s| NativeSeg { entry: base + s.entry, ..*s })
                .collect(),
            seg_start: plan.seg_start.clone().into_boxed_slice(),
            term_entry: code.term_entry.map(|t| base + t),
            thunk: base + code.thunk,
            slot_taken: code.slot_taken.map(|s| base + s),
            slot_seq: code.slot_seq.map(|s| base + s),
        };
        if let Some(pc) = self.dump_pc {
            if pc >= block.start && pc < block.end {
                dump_block(id, block, &nb, base, &a.code);
            }
        }
        NativeState::Ready(nb)
    }
}

fn dump_block(id: u32, block: &Block, nb: &NativeBlock, base: u32, code: &[u8]) {
    eprintln!(
        "--dump-native: block {} pc {:#x}..{:#x}, {} bytes at buffer offset {:#x}",
        id,
        block.start,
        block.end,
        code.len(),
        base
    );
    for (i, s) in nb.segs.iter().enumerate() {
        eprintln!(
            "  seg {}: steps ..{} ({} insts, {} cycles) entry {:#x}",
            i, s.end, s.count, s.cycles, s.entry
        );
    }
    if let Some(t) = nb.term_entry {
        eprintln!("  term entry {:#x} (kind {:?})", t, block.term.kind);
    }
    eprintln!("  thunk {:#x} slots taken={:?} seq={:?}", nb.thunk, nb.slot_taken, nb.slot_seq);
    let hex: Vec<String> = code.iter().map(|b| format!("{:02x}", b)).collect();
    for chunk in hex.chunks(16) {
        eprintln!("    {}", chunk.join(" "));
    }
}

// ---------------------------------------------------------------------------
// Block planning: segment formation + register allocation
// ---------------------------------------------------------------------------

struct Plan {
    /// (first, end) step ranges.
    segs: Vec<(usize, usize)>,
    seg_start: Vec<u16>,
    /// Lowerable terminator kind (Branch/Jump/IndirectJump only).
    term_kind: Option<TermKind>,
    /// Guest registers allocated to r12/r13/r14 (0 = slot unused).
    alloc: [u8; 3],
}

/// Non-synchronising ops the segment body can lower.
fn plain_lowerable(op: &Op) -> bool {
    matches!(
        op,
        Op::Alu { .. }
            | Op::AluImm { .. }
            | Op::Lui { .. }
            | Op::Auipc { .. }
            | Op::Mul { .. }
            | Op::Fence
    )
}

fn plan_block(block: &Block) -> Plan {
    let steps = &block.steps;
    let mut segs = Vec::new();
    let mut i = 0;
    while i < steps.len() {
        let op = &steps[i].op;
        let leads = matches!(op, Op::Load { .. } | Op::Store { .. })
            || (plain_lowerable(op) && !steps[i].sync);
        if !leads {
            i += 1;
            continue;
        }
        let first = i;
        let has_mem = matches!(op, Op::Load { .. } | Op::Store { .. });
        i += 1;
        while i < steps.len() && plain_lowerable(&steps[i].op) && !steps[i].sync {
            i += 1;
        }
        // A lone ALU step is cheaper through the Rust fast-path arm than
        // through a native call; memory ops always pay off (inline L0).
        if has_mem || i - first >= 2 {
            segs.push((first, i));
        }
    }

    let mut seg_start = vec![u16::MAX; steps.len()];
    for (s, &(first, _)) in segs.iter().enumerate() {
        seg_start[first] = s as u16;
    }

    let term_kind = match (&block.term.kind, &block.term.op) {
        (TermKind::Branch, Op::Branch { .. }) => Some(block.term.kind),
        (TermKind::Jump { .. }, Op::Jal { .. }) => Some(block.term.kind),
        (TermKind::IndirectJump, Op::Jalr { .. }) => Some(block.term.kind),
        _ => None,
    };

    // Register allocation: the three most-referenced guest registers
    // across the lowered segments (x0 excluded) live in r12/r13/r14 for
    // each segment's lifetime.
    let mut uses = [0u32; 32];
    for &(first, end) in &segs {
        for step in &steps[first..end] {
            let (rs1, rs2) = step.op.srcs();
            for r in [rs1, rs2].into_iter().flatten() {
                uses[r as usize] += 1;
            }
            if let Some(rd) = step.op.rd() {
                uses[rd as usize] += 1;
            }
        }
    }
    uses[0] = 0;
    let mut alloc = [0u8; 3];
    for slot in &mut alloc {
        let (best, &n) = uses.iter().enumerate().max_by_key(|&(_, &n)| n).unwrap();
        if n == 0 {
            break;
        }
        *slot = best as u8;
        uses[best] = 0;
    }

    Plan { segs, seg_start, term_kind, alloc }
}

// ---------------------------------------------------------------------------
// Emission
// ---------------------------------------------------------------------------

/// Host registers holding allocated guest registers.
const ALLOC_HOST: [Reg; 3] = [x86::R12, x86::R13, x86::R14];

struct BlockCode {
    segs: Vec<NativeSeg>,
    term_entry: Option<u32>,
    thunk: u32,
    slot_taken: Option<u32>,
    slot_seq: Option<u32>,
}

fn emit_prologue(a: &mut Asm) {
    a.push_r(x86::RBX);
    a.push_r(x86::RBP);
    a.push_r(x86::R12);
    a.push_r(x86::R13);
    a.push_r(x86::R14);
    a.mov_rr(x86::RBX, x86::RDI);
    a.mov_rm(x86::RBP, x86::RDI, OFF_REGS);
    a.jmp_r(x86::RSI);
}

fn emit_epilogue(a: &mut Asm) {
    a.pop_r(x86::R14);
    a.pop_r(x86::R13);
    a.pop_r(x86::R12);
    a.pop_r(x86::RBP);
    a.pop_r(x86::RBX);
    a.ret();
}

fn emit_exit(a: &mut Asm, code: u64) {
    a.mov_imm(x86::RAX, code);
    emit_epilogue(a);
}

fn host_for(alloc: &[u8; 3], g: u8) -> Option<Reg> {
    alloc.iter().position(|&x| x == g && g != 0).map(|i| ALLOC_HOST[i])
}

/// Materialise guest register `g` into host register `dst`.
fn load_guest(a: &mut Asm, alloc: &[u8; 3], g: u8, dst: Reg) {
    if g == 0 {
        a.alu32_rr(AluKind::Xor, dst, dst);
    } else if let Some(h) = host_for(alloc, g) {
        a.mov_rr(dst, h);
    } else {
        a.mov_rm(dst, x86::RBP, g as i32 * 8);
    }
}

/// Store host register `src` into guest register `g` (x0 writes vanish).
fn store_guest(a: &mut Asm, alloc: &[u8; 3], g: u8, src: Reg) {
    if g == 0 {
        return;
    }
    if let Some(h) = host_for(alloc, g) {
        a.mov_rr(h, src);
    } else {
        a.mov_mr(x86::RBP, g as i32 * 8, src);
    }
}

fn load_allocs(a: &mut Asm, alloc: &[u8; 3]) {
    for (i, &g) in alloc.iter().enumerate() {
        if g != 0 {
            a.mov_rm(ALLOC_HOST[i], x86::RBP, g as i32 * 8);
        }
    }
}

fn spill_allocs(a: &mut Asm, alloc: &[u8; 3]) {
    for (i, &g) in alloc.iter().enumerate() {
        if g != 0 {
            a.mov_mr(x86::RBP, g as i32 * 8, ALLOC_HOST[i]);
        }
    }
}

fn cc_of(cond: BrCond) -> u8 {
    match cond {
        BrCond::Eq => x86::CC_E,
        BrCond::Ne => x86::CC_NE,
        BrCond::Lt => x86::CC_L,
        BrCond::Ge => x86::CC_GE,
        BrCond::Ltu => x86::CC_B,
        BrCond::Geu => x86::CC_AE,
    }
}

/// rax = alu(op, word, rax, rcx) — the exact semantics of
/// `sys::exec::alu_value`. Shared by block codegen and `self_check`.
fn emit_alu_value(a: &mut Asm, op: AluOp, word: bool) {
    use AluKind::*;
    if !word {
        match op {
            AluOp::Add => a.alu_rr(Add, x86::RAX, x86::RCX),
            AluOp::Sub => a.alu_rr(Sub, x86::RAX, x86::RCX),
            AluOp::And => a.alu_rr(And, x86::RAX, x86::RCX),
            AluOp::Or => a.alu_rr(Or, x86::RAX, x86::RCX),
            AluOp::Xor => a.alu_rr(Xor, x86::RAX, x86::RCX),
            // x86 masks the cl count to 6 bits in 64-bit mode — exactly
            // `b as u32 & 63`.
            AluOp::Sll => a.shift_cl(ShiftKind::Shl, x86::RAX),
            AluOp::Srl => a.shift_cl(ShiftKind::Shr, x86::RAX),
            AluOp::Sra => a.shift_cl(ShiftKind::Sar, x86::RAX),
            AluOp::Slt | AluOp::Sltu => {
                a.alu_rr(Cmp, x86::RAX, x86::RCX);
                a.setcc(if op == AluOp::Slt { x86::CC_L } else { x86::CC_B }, x86::RAX);
                a.movzx8_rr(x86::RAX, x86::RAX);
            }
        }
    } else {
        match op {
            AluOp::Add => a.alu32_rr(Add, x86::RAX, x86::RCX),
            AluOp::Sub => a.alu32_rr(Sub, x86::RAX, x86::RCX),
            AluOp::And => a.alu32_rr(And, x86::RAX, x86::RCX),
            AluOp::Or => a.alu32_rr(Or, x86::RAX, x86::RCX),
            AluOp::Xor => a.alu32_rr(Xor, x86::RAX, x86::RCX),
            // 32-bit shifts mask cl to 5 bits — exactly `b32 & 31`.
            AluOp::Sll => a.shift32_cl(ShiftKind::Shl, x86::RAX),
            AluOp::Srl => a.shift32_cl(ShiftKind::Shr, x86::RAX),
            AluOp::Sra => a.shift32_cl(ShiftKind::Sar, x86::RAX),
            AluOp::Slt | AluOp::Sltu => {
                a.alu32_rr(Cmp, x86::RAX, x86::RCX);
                a.setcc(if op == AluOp::Slt { x86::CC_L } else { x86::CC_B }, x86::RAX);
                a.movzx8_rr(x86::RAX, x86::RAX);
                return; // 0/1 needs no sign extension
            }
        }
        a.movsxd_rr(x86::RAX, x86::RAX);
    }
}

/// rax = alu(op, word, rax, imm as i64 as u64) — immediate form.
fn emit_alu_imm(a: &mut Asm, op: AluOp, word: bool, imm: i32) {
    use AluKind::*;
    if !word {
        match op {
            AluOp::Add => a.alu_ri(Add, x86::RAX, imm),
            AluOp::Sub => a.alu_ri(Sub, x86::RAX, imm),
            AluOp::And => a.alu_ri(And, x86::RAX, imm),
            AluOp::Or => a.alu_ri(Or, x86::RAX, imm),
            AluOp::Xor => a.alu_ri(Xor, x86::RAX, imm),
            AluOp::Sll => a.shift_ri(ShiftKind::Shl, x86::RAX, (imm as u32 & 63) as u8),
            AluOp::Srl => a.shift_ri(ShiftKind::Shr, x86::RAX, (imm as u32 & 63) as u8),
            AluOp::Sra => a.shift_ri(ShiftKind::Sar, x86::RAX, (imm as u32 & 63) as u8),
            AluOp::Slt | AluOp::Sltu => {
                a.cmp_ri(x86::RAX, imm);
                a.setcc(if op == AluOp::Slt { x86::CC_L } else { x86::CC_B }, x86::RAX);
                a.movzx8_rr(x86::RAX, x86::RAX);
            }
        }
    } else {
        match op {
            AluOp::Add => a.alu32_ri(Add, x86::RAX, imm),
            AluOp::Sub => a.alu32_ri(Sub, x86::RAX, imm),
            AluOp::And => a.alu32_ri(And, x86::RAX, imm),
            AluOp::Or => a.alu32_ri(Or, x86::RAX, imm),
            AluOp::Xor => a.alu32_ri(Xor, x86::RAX, imm),
            AluOp::Sll => a.shift32_ri(ShiftKind::Shl, x86::RAX, (imm as u32 & 31) as u8),
            AluOp::Srl => a.shift32_ri(ShiftKind::Shr, x86::RAX, (imm as u32 & 31) as u8),
            AluOp::Sra => a.shift32_ri(ShiftKind::Sar, x86::RAX, (imm as u32 & 31) as u8),
            AluOp::Slt | AluOp::Sltu => {
                a.alu32_ri(Cmp, x86::RAX, imm);
                a.setcc(if op == AluOp::Slt { x86::CC_L } else { x86::CC_B }, x86::RAX);
                a.movzx8_rr(x86::RAX, x86::RAX);
                return;
            }
        }
        a.movsxd_rr(x86::RAX, x86::RAX);
    }
}

/// Emit one whole block's native code into `a`. Offsets in the returned
/// `BlockCode` are relative to `a`'s start.
fn emit_block(
    a: &mut Asm,
    id: u32,
    block: &Block,
    plan: &Plan,
    line_shift: u32,
    profile: bool,
) -> BlockCode {
    let mut segs = Vec::with_capacity(plan.segs.len());
    for &(first, end) in &plan.segs {
        let entry = emit_segment(a, block, first, end, &plan.alloc, line_shift, profile);
        let cycles: u64 = block.steps[first..end].iter().map(|s| s.cycles as u64).sum();
        segs.push(NativeSeg {
            end: end as u16,
            count: (end - first) as u16,
            entry,
            cycles,
        });
    }

    let (term_entry, slot_taken, slot_seq) = match plan.term_kind {
        Some(kind) => emit_term(a, block, kind),
        None => (None, None, None),
    };

    // Identify thunk: patched chain jumps land here and exit to Rust with
    // this block's id.
    let thunk = a.len() as u32;
    emit_exit(a, (id as u64) << 8 | RC_CHAINED);

    BlockCode { segs, term_entry, thunk, slot_taken, slot_seq }
}

/// Emit steps `[first, end)` as one native segment; returns its entry.
fn emit_segment(
    a: &mut Asm,
    block: &Block,
    first: usize,
    end: usize,
    alloc: &[u8; 3],
    line_shift: u32,
    profile: bool,
) -> u32 {
    let entry = a.len() as u32;
    load_allocs(a, alloc);
    let mut trap_jumps = Vec::new();
    for si in first..end {
        let step = &block.steps[si];
        match step.op {
            Op::Load { width, signed, rd, rs1, imm } => {
                emit_load(a, alloc, line_shift, width, signed, rd, rs1, imm, &mut trap_jumps);
            }
            Op::Store { width, rs1, rs2, imm } => {
                emit_store(a, alloc, line_shift, width, rs1, rs2, imm, &mut trap_jumps);
            }
            Op::Alu { op, word, rd, rs1, rs2 } => {
                load_guest(a, alloc, rs1, x86::RAX);
                load_guest(a, alloc, rs2, x86::RCX);
                emit_alu_value(a, op, word);
                store_guest(a, alloc, rd, x86::RAX);
            }
            Op::AluImm { op, word, rd, rs1, imm } => {
                load_guest(a, alloc, rs1, x86::RAX);
                emit_alu_imm(a, op, word, imm);
                store_guest(a, alloc, rd, x86::RAX);
            }
            Op::Lui { rd, imm } => {
                a.mov_imm(x86::RAX, imm as i64 as u64);
                store_guest(a, alloc, rd, x86::RAX);
            }
            Op::Auipc { rd, imm } => {
                let pc = block.start + step.pc_off as u64;
                a.mov_imm(x86::RAX, pc.wrapping_add(imm as i64 as u64));
                store_guest(a, alloc, rd, x86::RAX);
            }
            Op::Mul { op, word, rd, rs1, rs2 } => {
                load_guest(a, alloc, rs1, x86::RDI);
                load_guest(a, alloc, rs2, x86::RSI);
                a.mov32_ri(x86::RDX, pack_mul(op, word));
                a.mov_rm(x86::RAX, x86::RBX, OFF_HMUL);
                a.call_r(x86::RAX);
                store_guest(a, alloc, rd, x86::RAX);
            }
            Op::Fence => {}
            _ => unreachable!("non-lowerable step in segment"),
        }
    }
    spill_allocs(a, alloc);
    if profile {
        // *ctx.prof_cycles += segment cycles — fully-retired exit only;
        // the RC_TRAP exit below retires nothing and charges nothing.
        let cycles: u64 = block.steps[first..end].iter().map(|s| s.cycles as u64).sum();
        a.mov_rm(x86::R8, x86::RBX, OFF_PROF);
        a.mov_rm(x86::RAX, x86::R8, 0);
        a.alu_ri(AluKind::Add, x86::RAX, cycles as i32);
        a.mov_mr(x86::R8, 0, x86::RAX);
    }
    emit_exit(a, RC_SEG_DONE);

    if !trap_jumps.is_empty() {
        let trap = a.len();
        for j in trap_jumps {
            a.patch_rel32(j, trap);
        }
        spill_allocs(a, alloc);
        emit_exit(a, RC_TRAP);
    }
    entry
}

/// rax = guest rs1 + imm; then the L0 probe. Jumps to a local slow path
/// (which calls the Rust helper) on misalignment or L0 miss.
/// On the hit path, leaves rsi = host address and bumps the access
/// counter. `write` selects the write-hit tag check + reservation guard.
fn emit_probe(
    a: &mut Asm,
    alloc: &[u8; 3],
    line_shift: u32,
    width: MemWidth,
    rs1: u8,
    imm: i32,
    write: bool,
) -> Vec<usize> {
    let mut slow = Vec::new();
    load_guest(a, alloc, rs1, x86::RAX);
    if imm != 0 {
        a.alu_ri(AluKind::Add, x86::RAX, imm);
    }
    // Misaligned line-crossing accesses take the slow path, which re-runs
    // the full Rust check and raises the trap (byte accesses never cross).
    let line_mask = (1u64 << line_shift) - 1;
    if width != MemWidth::B {
        a.mov_rr(x86::RDX, x86::RAX);
        a.alu_ri(AluKind::And, x86::RDX, line_mask as i32);
        a.cmp_ri(x86::RDX, (line_mask + 1 - width.bytes()) as i32);
        slow.push(a.jcc_rel32(x86::CC_A));
    }
    // r9 = vtag, rdx = index, rsi = packed tag word.
    a.mov_rr(x86::R9, x86::RAX);
    a.shift_ri(ShiftKind::Shr, x86::R9, line_shift as u8);
    a.mov_rr(x86::RDX, x86::R9);
    a.alu_ri(AluKind::And, x86::RDX, (L0_ENTRIES - 1) as i32);
    a.mov_rm(x86::R8, x86::RBX, OFF_DTAGS);
    a.mov_rm_sib8(x86::RSI, x86::R8, x86::RDX);
    if write {
        // Figure 4 write check: vtag << 1 == T.
        a.mov_rr(x86::RCX, x86::R9);
        a.shift_ri(ShiftKind::Shl, x86::RCX, 1);
        a.alu_rr(AluKind::Cmp, x86::RSI, x86::RCX);
        slow.push(a.jcc_rel32(x86::CC_NE));
        // Live LR reservations force the slow path (reservation clearing
        // needs the Rust store-commit protocol).
        a.mov_rm(x86::R8, x86::RBX, OFF_RESV);
        a.mov32_rm(x86::RCX, x86::R8, 0);
        a.test_rr(x86::RCX, x86::RCX);
        slow.push(a.jcc_rel32(x86::CC_NE));
    } else {
        // Figure 4 read check: T >> 1 == vtag.
        a.mov_rr(x86::RCX, x86::RSI);
        a.shift_ri(ShiftKind::Shr, x86::RCX, 1);
        a.alu_rr(AluKind::Cmp, x86::RCX, x86::R9);
        slow.push(a.jcc_rel32(x86::CC_NE));
    }
    // Hit: bump the access counter (the slow path must leave counters
    // untouched — the helper's Rust lookup does the counting there).
    a.mov_rm(x86::R8, x86::RBX, OFF_DACC);
    a.add_m_i8(x86::R8, 0, 1);
    // rsi = host address = (vaddr ^ xors[idx]) + dram_bias.
    a.mov_rm(x86::R8, x86::RBX, OFF_DXORS);
    a.mov_rm_sib8(x86::RSI, x86::R8, x86::RDX);
    a.alu_rr(AluKind::Xor, x86::RSI, x86::RAX);
    a.mov_rm(x86::R8, x86::RBX, OFF_BIAS);
    a.alu_rr(AluKind::Add, x86::RSI, x86::R8);
    slow
}

#[allow(clippy::too_many_arguments)]
fn emit_load(
    a: &mut Asm,
    alloc: &[u8; 3],
    line_shift: u32,
    width: MemWidth,
    signed: bool,
    rd: u8,
    rs1: u8,
    imm: i32,
    trap_jumps: &mut Vec<usize>,
) {
    let slow = emit_probe(a, alloc, line_shift, width, rs1, imm, false);
    // rcx = sign/zero-extended loaded value (matches `sext_load`).
    match (width, signed) {
        (MemWidth::B, false) => a.movzx8_rm(x86::RCX, x86::RSI, 0),
        (MemWidth::B, true) => a.movsx8_rm(x86::RCX, x86::RSI, 0),
        (MemWidth::H, false) => a.movzx16_rm(x86::RCX, x86::RSI, 0),
        (MemWidth::H, true) => a.movsx16_rm(x86::RCX, x86::RSI, 0),
        (MemWidth::W, false) => a.mov32_rm(x86::RCX, x86::RSI, 0),
        (MemWidth::W, true) => a.movsxd_rm(x86::RCX, x86::RSI, 0),
        (MemWidth::D, _) => a.mov_rm(x86::RCX, x86::RSI, 0),
    }
    let write_rd = a.len();
    store_guest(a, alloc, rd, x86::RCX);
    let done = a.jmp_rel32();
    // Slow path: helper_read(ctx, vaddr, packed) -> { rax = value, rdx = trap }.
    let slow_at = a.len();
    for j in slow {
        a.patch_rel32(j, slow_at);
    }
    a.mov_rr(x86::RDI, x86::RBX);
    a.mov_rr(x86::RSI, x86::RAX);
    a.mov32_ri(x86::RDX, pack_mem(width, signed));
    a.mov_rm(x86::RAX, x86::RBX, OFF_HREAD);
    a.call_r(x86::RAX);
    a.test_rr(x86::RDX, x86::RDX);
    trap_jumps.push(a.jcc_rel32(x86::CC_NE));
    a.mov_rr(x86::RCX, x86::RAX);
    let back = a.jmp_rel32();
    a.patch_rel32(back, write_rd);
    let end = a.len();
    a.patch_rel32(done, end);
}

#[allow(clippy::too_many_arguments)]
fn emit_store(
    a: &mut Asm,
    alloc: &[u8; 3],
    line_shift: u32,
    width: MemWidth,
    rs1: u8,
    rs2: u8,
    imm: i32,
    trap_jumps: &mut Vec<usize>,
) {
    let slow = emit_probe(a, alloc, line_shift, width, rs1, imm, true);
    load_guest(a, alloc, rs2, x86::RCX);
    match width {
        MemWidth::B => a.mov8_mr(x86::RSI, 0, x86::RCX),
        MemWidth::H => a.mov16_mr(x86::RSI, 0, x86::RCX),
        MemWidth::W => a.mov32_mr(x86::RSI, 0, x86::RCX),
        MemWidth::D => a.mov_mr(x86::RSI, 0, x86::RCX),
    }
    let done = a.jmp_rel32();
    // Slow path: helper_write(ctx, vaddr, value, packed) -> 0 ok / 1 trap.
    let slow_at = a.len();
    for j in slow {
        a.patch_rel32(j, slow_at);
    }
    a.mov_rr(x86::RDI, x86::RBX);
    a.mov_rr(x86::RSI, x86::RAX);
    load_guest(a, alloc, rs2, x86::RDX);
    a.mov32_ri(x86::RCX, pack_mem(width, false));
    a.mov_rm(x86::RAX, x86::RBX, OFF_HWRITE);
    a.call_r(x86::RAX);
    a.test_rr(x86::RAX, x86::RAX);
    trap_jumps.push(a.jcc_rel32(x86::CC_NE));
    let end = a.len();
    a.patch_rel32(done, end);
}

/// Emit the terminator. Returns (entry, slot_taken, slot_seq) relative
/// offsets; slots are the rel32 fields of the chain `jmp`s.
fn emit_term(
    a: &mut Asm,
    block: &Block,
    kind: TermKind,
) -> (Option<u32>, Option<u32>, Option<u32>) {
    let term = &block.term;
    let pc = block.start + term.pc_off as u64;
    let npc = pc + term.len as u64;
    let entry = a.len() as u32;
    match (kind, term.op) {
        (TermKind::Branch, Op::Branch { cond, rs1, rs2, .. }) => {
            let none = [0u8; 3]; // terminators use no allocated registers
            load_guest(a, &none, rs1, x86::RAX);
            load_guest(a, &none, rs2, x86::RCX);
            a.alu_rr(AluKind::Cmp, x86::RAX, x86::RCX);
            a.setcc(cc_of(cond), x86::RAX);
            a.movzx8_rr(x86::RAX, x86::RAX);
            a.mov_mr(x86::RBX, OFF_TAKEN, x86::RAX);
            a.test_rr(x86::RAX, x86::RAX);
            let to_taken = a.jcc_rel32(x86::CC_NE);
            // Sequential chain slot: a patchable jmp, initially to the
            // plain RC_TERM exit just below.
            let slot_seq = a.jmp_rel32();
            let taken_at = a.len();
            a.patch_rel32(to_taken, taken_at);
            let slot_taken = a.jmp_rel32();
            let exit = a.len();
            a.patch_rel32(slot_seq, exit);
            a.patch_rel32(slot_taken, exit);
            emit_exit(a, RC_TERM);
            (Some(entry), Some(slot_taken as u32), Some(slot_seq as u32))
        }
        (TermKind::Jump { .. }, Op::Jal { rd, .. }) => {
            if rd != 0 {
                a.mov_imm(x86::RAX, npc);
                a.mov_mr(x86::RBP, rd as i32 * 8, x86::RAX);
            }
            let slot_taken = a.jmp_rel32();
            let exit = a.len();
            a.patch_rel32(slot_taken, exit);
            emit_exit(a, RC_TERM);
            (Some(entry), Some(slot_taken as u32), None)
        }
        (TermKind::IndirectJump, Op::Jalr { rd, rs1, imm }) => {
            let none = [0u8; 3];
            // Target before the rd write: rd may alias rs1.
            load_guest(a, &none, rs1, x86::RAX);
            if imm != 0 {
                a.alu_ri(AluKind::Add, x86::RAX, imm);
            }
            a.alu_ri(AluKind::And, x86::RAX, -2);
            a.mov_mr(x86::RBX, OFF_JTARGET, x86::RAX);
            if rd != 0 {
                a.mov_imm(x86::RAX, npc);
                a.mov_mr(x86::RBP, rd as i32 * 8, x86::RAX);
            }
            let slot_taken = a.jmp_rel32();
            let exit = a.len();
            a.patch_rel32(slot_taken, exit);
            emit_exit(a, RC_TERM);
            (Some(entry), Some(slot_taken as u32), None)
        }
        _ => (None, None, None),
    }
}

// ---------------------------------------------------------------------------
// Runtime self-check
// ---------------------------------------------------------------------------

/// Verify the ALU and branch-condition lowering against the Rust
/// semantics on an edge-case vector, executing real emitted code. Run
/// once (cached by `dbt::native_available`); on any mismatch the native
/// backend reports itself unavailable instead of running wrong code.
pub fn self_check() -> bool {
    const VALS: [u64; 10] = [
        0,
        1,
        u64::MAX,
        i64::MIN as u64,
        i64::MAX as u64,
        0x7fff_ffff,
        0x8000_0000,
        0xffff_ffff,
        63,
        0x1234_5678_9abc_def0,
    ];
    const IMMS: [i32; 6] = [0, 1, -1, 31, 63, -2048];
    const ALU_OPS: [AluOp; 10] = [
        AluOp::Add,
        AluOp::Sub,
        AluOp::Sll,
        AluOp::Slt,
        AluOp::Sltu,
        AluOp::Xor,
        AluOp::Srl,
        AluOp::Sra,
        AluOp::Or,
        AluOp::And,
    ];
    const CONDS: [BrCond; 6] =
        [BrCond::Eq, BrCond::Ne, BrCond::Lt, BrCond::Ge, BrCond::Ltu, BrCond::Geu];

    let mut a = Asm::new();
    let mut probes: Vec<(usize, Box<dyn Fn(u64, u64) -> u64>)> = Vec::new();
    let mut probe = |a: &mut Asm, body: &dyn Fn(&mut Asm)| -> usize {
        let entry = a.len();
        a.mov_rr(x86::RAX, x86::RDI);
        a.mov_rr(x86::RCX, x86::RSI);
        body(a);
        a.ret();
        entry
    };
    for op in ALU_OPS {
        for word in [false, true] {
            let entry = probe(&mut a, &|a| emit_alu_value(a, op, word));
            probes.push((
                entry,
                Box::new(move |x, y| crate::sys::exec::alu_value(op, word, x, y)),
            ));
            for imm in IMMS {
                let entry = probe(&mut a, &|a| emit_alu_imm(a, op, word, imm));
                probes.push((
                    entry,
                    Box::new(move |x, _| {
                        crate::sys::exec::alu_value(op, word, x, imm as i64 as u64)
                    }),
                ));
            }
        }
    }
    for cond in CONDS {
        let entry = probe(&mut a, &|a| {
            a.alu_rr(AluKind::Cmp, x86::RAX, x86::RCX);
            a.setcc(cc_of(cond), x86::RAX);
            a.movzx8_rr(x86::RAX, x86::RAX);
        });
        probes.push((entry, Box::new(move |x, y| cond.eval(x, y) as u64)));
    }

    let mut buf = match ExecBuf::new((a.len() + 4095) & !4095) {
        Some(b) => b,
        None => return false,
    };
    let base = match buf.append(&a.code) {
        Some(b) => b,
        None => return false,
    };
    buf.make_exec();
    for (entry, reference) in &probes {
        let f: extern "sysv64" fn(u64, u64) -> u64 = unsafe {
            std::mem::transmute(buf.addr(base + *entry as u32) as *const u8)
        };
        for &x in &VALS {
            for &y in &VALS {
                if f(x, y) != reference(x, y) {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::ptr::addr_of;

    #[test]
    fn ctx_offsets_match_layout() {
        let ctx = NativeCtx {
            regs: std::ptr::null_mut(),
            d_tags: std::ptr::null(),
            d_xors: std::ptr::null(),
            d_acc: std::ptr::null_mut(),
            dram_bias: 0,
            resv: std::ptr::null(),
            jump_target: 0,
            taken: 0,
            helper_read: 0,
            helper_write: 0,
            helper_mul: 0,
            trap_cause: 0,
            trap_tval: 0,
            hart: std::ptr::null_mut(),
            sys: std::ptr::null_mut(),
            prof_cycles: std::ptr::null_mut(),
        };
        let base = &ctx as *const NativeCtx as usize;
        let off = |p: usize| (p - base) as i32;
        assert_eq!(off(addr_of!(ctx.regs) as usize), OFF_REGS);
        assert_eq!(off(addr_of!(ctx.d_tags) as usize), OFF_DTAGS);
        assert_eq!(off(addr_of!(ctx.d_xors) as usize), OFF_DXORS);
        assert_eq!(off(addr_of!(ctx.d_acc) as usize), OFF_DACC);
        assert_eq!(off(addr_of!(ctx.dram_bias) as usize), OFF_BIAS);
        assert_eq!(off(addr_of!(ctx.resv) as usize), OFF_RESV);
        assert_eq!(off(addr_of!(ctx.jump_target) as usize), OFF_JTARGET);
        assert_eq!(off(addr_of!(ctx.taken) as usize), OFF_TAKEN);
        assert_eq!(off(addr_of!(ctx.helper_read) as usize), OFF_HREAD);
        assert_eq!(off(addr_of!(ctx.helper_write) as usize), OFF_HWRITE);
        assert_eq!(off(addr_of!(ctx.helper_mul) as usize), OFF_HMUL);
        assert_eq!(off(addr_of!(ctx.trap_cause) as usize), OFF_TCAUSE);
        assert_eq!(off(addr_of!(ctx.trap_tval) as usize), OFF_TTVAL);
        assert_eq!(off(addr_of!(ctx.prof_cycles) as usize), OFF_PROF);
    }

    #[test]
    fn mem_and_mul_packing_roundtrip() {
        for width in [MemWidth::B, MemWidth::H, MemWidth::W, MemWidth::D] {
            for signed in [false, true] {
                assert_eq!(unpack_mem(pack_mem(width, signed)), (width, signed));
            }
        }
        for op in [
            MulOp::Mul,
            MulOp::Mulh,
            MulOp::Mulhsu,
            MulOp::Mulhu,
            MulOp::Div,
            MulOp::Divu,
            MulOp::Rem,
            MulOp::Remu,
        ] {
            for word in [false, true] {
                assert_eq!(unpack_mul(pack_mul(op, word)), (op, word));
            }
        }
    }

    #[test]
    fn alu_lowering_self_check_passes() {
        assert!(self_check(), "emitted ALU code diverges from Rust semantics");
    }
}
