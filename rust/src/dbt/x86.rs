//! Minimal x86-64 instruction encoder for the native DBT backend.
//!
//! Emits raw machine code into a `Vec<u8>`. Coverage is exactly what the
//! block codegen pass (`dbt/codegen.rs`) needs: 64/32-bit ALU reg-reg and
//! reg-imm forms, moves between registers / memory (8/16/32/64-bit widths,
//! zero/sign extension), shifts, compare + setcc, relative jumps with
//! post-hoc patching, indirect calls, and push/pop/ret for the trampoline.
//!
//! The encoder is pure byte emission with no host-architecture dependence,
//! so it compiles (and its unit tests run) on every target; only the code
//! *executor* (`exec_buf.rs` / `codegen.rs`) is x86-64-gated.

/// Host register number (the 4-bit encoding: REX.B/R extends to 8-15).
pub type Reg = u8;

pub const RAX: Reg = 0;
pub const RCX: Reg = 1;
pub const RDX: Reg = 2;
pub const RBX: Reg = 3;
pub const RSP: Reg = 4;
pub const RBP: Reg = 5;
pub const RSI: Reg = 6;
pub const RDI: Reg = 7;
pub const R8: Reg = 8;
pub const R9: Reg = 9;
pub const R10: Reg = 10;
pub const R11: Reg = 11;
pub const R12: Reg = 12;
pub const R13: Reg = 13;
pub const R14: Reg = 14;
pub const R15: Reg = 15;

/// Condition codes (the `cc` nibble of `setcc` / `jcc`).
pub const CC_B: u8 = 0x2; // below (unsigned <)
pub const CC_AE: u8 = 0x3; // above-or-equal (unsigned >=)
pub const CC_E: u8 = 0x4; // equal
pub const CC_NE: u8 = 0x5; // not equal
pub const CC_A: u8 = 0x7; // above (unsigned >)
pub const CC_L: u8 = 0xC; // less (signed <)
pub const CC_GE: u8 = 0xD; // greater-or-equal (signed >=)

/// Two-operand ALU opcodes, encoded as the /r opcode for the
/// `op r/m, reg` form; the reg-imm form uses `0x81 /modrm_ext`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AluKind {
    Add,
    Or,
    And,
    Sub,
    Xor,
    Cmp,
}

impl AluKind {
    /// Opcode byte for the `op r/m, reg` (store-form, MR) encoding.
    fn mr_opcode(self) -> u8 {
        match self {
            AluKind::Add => 0x01,
            AluKind::Or => 0x09,
            AluKind::And => 0x21,
            AluKind::Sub => 0x29,
            AluKind::Xor => 0x31,
            AluKind::Cmp => 0x39,
        }
    }

    /// ModRM `/n` extension for the `0x81` imm32 form.
    fn imm_ext(self) -> u8 {
        match self {
            AluKind::Add => 0,
            AluKind::Or => 1,
            AluKind::And => 4,
            AluKind::Sub => 5,
            AluKind::Xor => 6,
            AluKind::Cmp => 7,
        }
    }
}

/// Shift opcodes (ModRM `/n` extension of `0xC1` / `0xD3`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ShiftKind {
    Shl,
    Shr,
    Sar,
}

impl ShiftKind {
    fn ext(self) -> u8 {
        match self {
            ShiftKind::Shl => 4,
            ShiftKind::Shr => 5,
            ShiftKind::Sar => 7,
        }
    }
}

/// Byte-buffer assembler.
#[derive(Default)]
pub struct Asm {
    pub code: Vec<u8>,
}

impl Asm {
    pub fn new() -> Asm {
        Asm { code: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.code.len()
    }

    pub fn is_empty(&self) -> bool {
        self.code.is_empty()
    }

    fn byte(&mut self, b: u8) {
        self.code.push(b);
    }

    fn imm32(&mut self, v: u32) {
        self.code.extend_from_slice(&v.to_le_bytes());
    }

    fn imm64(&mut self, v: u64) {
        self.code.extend_from_slice(&v.to_le_bytes());
    }

    /// REX prefix. `w` selects 64-bit operands; `r` is the ModRM.reg
    /// register, `x` the SIB index, `b` the ModRM.rm / SIB base register.
    /// Emitted unconditionally when `w` or any high register requires it.
    fn rex(&mut self, w: bool, r: Reg, x: Reg, b: Reg) {
        let v = 0x40u8
            | (w as u8) << 3
            | ((r >> 3) & 1) << 2
            | ((x >> 3) & 1) << 1
            | ((b >> 3) & 1);
        if v != 0x40 || w {
            self.byte(v);
        }
    }

    /// REX that must also be emitted for low byte registers spl/bpl/sil/dil
    /// (8-bit operations on rsp/rbp/rsi/rdi need a REX to avoid the legacy
    /// ah/ch/dh/bh encodings).
    fn rex_byte_op(&mut self, r: Reg, b: Reg) {
        let v = 0x40u8 | ((r >> 3) & 1) << 2 | ((b >> 3) & 1);
        if v != 0x40 || (4..8).contains(&r) || (4..8).contains(&b) {
            self.byte(v);
        }
    }

    fn modrm(&mut self, md: u8, reg: Reg, rm: Reg) {
        self.byte(md << 6 | (reg & 7) << 3 | (rm & 7));
    }

    /// ModRM + displacement for a `[base + disp32]` memory operand.
    /// Handles the two irregular base encodings: base&7 == 4 (rsp/r12)
    /// needs a SIB byte, and base&7 == 5 (rbp/r13) has no disp-less form.
    fn mem(&mut self, reg: Reg, base: Reg, disp: i32) {
        let need_sib = base & 7 == 4;
        let small = i8::try_from(disp).is_ok();
        let md = if disp == 0 && base & 7 != 5 {
            0
        } else if small {
            1
        } else {
            2
        };
        self.modrm(md, reg, base);
        if need_sib {
            // scale=0, index=100 (none), base=100 (only rsp/r12 reach here).
            self.byte(0x24);
        }
        match md {
            1 => self.byte(disp as i8 as u8),
            2 => self.imm32(disp as u32),
            _ => {}
        }
    }

    /// ModRM + SIB for `[base + index*scale]` (scale = 1/2/4/8).
    fn mem_sib(&mut self, reg: Reg, base: Reg, index: Reg, scale: u8) {
        debug_assert!(index & 7 != 4, "rsp cannot be an index");
        let ss = match scale {
            1 => 0,
            2 => 1,
            4 => 2,
            8 => 3,
            _ => unreachable!("bad scale"),
        };
        if base & 7 == 5 {
            // rbp/r13 base needs an explicit disp8 of 0.
            self.modrm(1, reg, 4);
            self.byte(ss << 6 | (index & 7) << 3 | (base & 7));
            self.byte(0);
        } else {
            self.modrm(0, reg, 4);
            self.byte(ss << 6 | (index & 7) << 3 | (base & 7));
        }
    }

    // ---- stack / control ----

    pub fn push_r(&mut self, r: Reg) {
        self.rex(false, 0, 0, r);
        self.byte(0x50 + (r & 7));
    }

    pub fn pop_r(&mut self, r: Reg) {
        self.rex(false, 0, 0, r);
        self.byte(0x58 + (r & 7));
    }

    pub fn ret(&mut self) {
        self.byte(0xC3);
    }

    /// `call reg` (indirect near call).
    pub fn call_r(&mut self, r: Reg) {
        self.rex(false, 0, 0, r);
        self.byte(0xFF);
        self.modrm(3, 2, r);
    }

    /// `jmp reg` (indirect near jump).
    pub fn jmp_r(&mut self, r: Reg) {
        self.rex(false, 0, 0, r);
        self.byte(0xFF);
        self.modrm(3, 4, r);
    }

    /// `jmp rel32`; returns the offset of the rel32 field for patching.
    pub fn jmp_rel32(&mut self) -> usize {
        self.byte(0xE9);
        let at = self.code.len();
        self.imm32(0);
        at
    }

    /// `jcc rel32`; returns the offset of the rel32 field for patching.
    pub fn jcc_rel32(&mut self, cc: u8) -> usize {
        self.byte(0x0F);
        self.byte(0x80 + cc);
        let at = self.code.len();
        self.imm32(0);
        at
    }

    /// Patch a previously emitted rel32 field (offset from `jmp_rel32` /
    /// `jcc_rel32`) to jump to `target` (an offset within this buffer).
    pub fn patch_rel32(&mut self, at: usize, target: usize) {
        let rel = (target as i64 - (at as i64 + 4)) as i32;
        self.code[at..at + 4].copy_from_slice(&rel.to_le_bytes());
    }

    /// Compute the rel32 value for a jump whose rel32 field lives at
    /// absolute address `field_addr`, targeting absolute address `target`.
    pub fn rel32_for(field_addr: u64, target: u64) -> i32 {
        (target.wrapping_sub(field_addr.wrapping_add(4))) as i64 as i32
    }

    // ---- moves ----

    /// `mov dst, src` (64-bit reg-reg).
    pub fn mov_rr(&mut self, dst: Reg, src: Reg) {
        self.rex(true, src, 0, dst);
        self.byte(0x89);
        self.modrm(3, src, dst);
    }

    /// `mov dst, imm64` (movabs).
    pub fn mov_ri64(&mut self, dst: Reg, imm: u64) {
        self.rex(true, 0, 0, dst);
        self.byte(0xB8 + (dst & 7));
        self.imm64(imm);
    }

    /// `mov dst, imm32` sign-extended to 64 bits (REX.W C7 /0).
    pub fn mov_ri32s(&mut self, dst: Reg, imm: i32) {
        self.rex(true, 0, 0, dst);
        self.byte(0xC7);
        self.modrm(3, 0, dst);
        self.imm32(imm as u32);
    }

    /// `mov dst32, imm32` (zero-extends to 64 bits).
    pub fn mov32_ri(&mut self, dst: Reg, imm: u32) {
        self.rex(false, 0, 0, dst);
        self.byte(0xB8 + (dst & 7));
        self.imm32(imm);
    }

    /// Pick the shortest encoding that materialises `imm` into `dst`.
    pub fn mov_imm(&mut self, dst: Reg, imm: u64) {
        if let Ok(v) = i32::try_from(imm as i64) {
            if v >= 0 {
                self.mov32_ri(dst, v as u32);
            } else {
                self.mov_ri32s(dst, v);
            }
        } else if let Ok(v) = u32::try_from(imm) {
            self.mov32_ri(dst, v);
        } else {
            self.mov_ri64(dst, imm);
        }
    }

    /// `mov dst, [base + disp]` (64-bit load).
    pub fn mov_rm(&mut self, dst: Reg, base: Reg, disp: i32) {
        self.rex(true, dst, 0, base);
        self.byte(0x8B);
        self.mem(dst, base, disp);
    }

    /// `mov [base + disp], src` (64-bit store).
    pub fn mov_mr(&mut self, base: Reg, disp: i32, src: Reg) {
        self.rex(true, src, 0, base);
        self.byte(0x89);
        self.mem(src, base, disp);
    }

    /// `mov dst32, [base + disp]` (32-bit load, zero-extends).
    pub fn mov32_rm(&mut self, dst: Reg, base: Reg, disp: i32) {
        self.rex(false, dst, 0, base);
        self.byte(0x8B);
        self.mem(dst, base, disp);
    }

    /// `mov [base + disp], src32` (32-bit store).
    pub fn mov32_mr(&mut self, base: Reg, disp: i32, src: Reg) {
        self.rex(false, src, 0, base);
        self.byte(0x89);
        self.mem(src, base, disp);
    }

    /// `mov [base + disp], src16` (16-bit store).
    pub fn mov16_mr(&mut self, base: Reg, disp: i32, src: Reg) {
        self.byte(0x66);
        self.rex(false, src, 0, base);
        self.byte(0x89);
        self.mem(src, base, disp);
    }

    /// `mov [base + disp], src8` (8-bit store).
    pub fn mov8_mr(&mut self, base: Reg, disp: i32, src: Reg) {
        self.rex_byte_op(src, base);
        self.byte(0x88);
        self.mem(src, base, disp);
    }

    /// `movzx dst, byte [base + disp]`.
    pub fn movzx8_rm(&mut self, dst: Reg, base: Reg, disp: i32) {
        self.rex(false, dst, 0, base);
        self.byte(0x0F);
        self.byte(0xB6);
        self.mem(dst, base, disp);
    }

    /// `movsx dst, byte [base + disp]` (to 64 bits).
    pub fn movsx8_rm(&mut self, dst: Reg, base: Reg, disp: i32) {
        self.rex(true, dst, 0, base);
        self.byte(0x0F);
        self.byte(0xBE);
        self.mem(dst, base, disp);
    }

    /// `movzx dst, word [base + disp]`.
    pub fn movzx16_rm(&mut self, dst: Reg, base: Reg, disp: i32) {
        self.rex(false, dst, 0, base);
        self.byte(0x0F);
        self.byte(0xB7);
        self.mem(dst, base, disp);
    }

    /// `movsx dst, word [base + disp]` (to 64 bits).
    pub fn movsx16_rm(&mut self, dst: Reg, base: Reg, disp: i32) {
        self.rex(true, dst, 0, base);
        self.byte(0x0F);
        self.byte(0xBF);
        self.mem(dst, base, disp);
    }

    /// `movsxd dst, dword [base + disp]` (32→64 sign extension load).
    pub fn movsxd_rm(&mut self, dst: Reg, base: Reg, disp: i32) {
        self.rex(true, dst, 0, base);
        self.byte(0x63);
        self.mem(dst, base, disp);
    }

    /// `movsxd dst, src32` (reg-reg 32→64 sign extension).
    pub fn movsxd_rr(&mut self, dst: Reg, src: Reg) {
        self.rex(true, dst, 0, src);
        self.byte(0x63);
        self.modrm(3, dst, src);
    }

    /// `mov dst32, src32` (zero-extends into 64-bit dst).
    pub fn mov32_rr(&mut self, dst: Reg, src: Reg) {
        self.rex(false, src, 0, dst);
        self.byte(0x89);
        self.modrm(3, src, dst);
    }

    /// `movzx dst32, src8` (byte→dword zero-extend, reg form).
    pub fn movzx8_rr(&mut self, dst: Reg, src: Reg) {
        // REX needed for spl/sil etc. as source byte regs.
        let v = 0x40u8 | ((dst >> 3) & 1) << 2 | ((src >> 3) & 1);
        if v != 0x40 || (4..8).contains(&src) {
            self.byte(v);
        }
        self.byte(0x0F);
        self.byte(0xB6);
        self.modrm(3, dst, src);
    }

    /// `mov dst, [base + index*8]` (64-bit SIB-indexed load).
    pub fn mov_rm_sib8(&mut self, dst: Reg, base: Reg, index: Reg) {
        self.rex(true, dst, index, base);
        self.byte(0x8B);
        self.mem_sib(dst, base, index, 8);
    }

    // ---- ALU ----

    /// `op dst, src` (64-bit reg-reg).
    pub fn alu_rr(&mut self, op: AluKind, dst: Reg, src: Reg) {
        self.rex(true, src, 0, dst);
        self.byte(op.mr_opcode());
        self.modrm(3, src, dst);
    }

    /// `op dst32, src32` (32-bit reg-reg; zero-extends dst).
    pub fn alu32_rr(&mut self, op: AluKind, dst: Reg, src: Reg) {
        self.rex(false, src, 0, dst);
        self.byte(op.mr_opcode());
        self.modrm(3, src, dst);
    }

    /// `op dst, imm32` (sign-extended, 64-bit).
    pub fn alu_ri(&mut self, op: AluKind, dst: Reg, imm: i32) {
        self.rex(true, 0, 0, dst);
        self.byte(0x81);
        self.modrm(3, op.imm_ext(), dst);
        self.imm32(imm as u32);
    }

    /// `op dst32, imm32` (32-bit form; zero-extends dst).
    pub fn alu32_ri(&mut self, op: AluKind, dst: Reg, imm: i32) {
        self.rex(false, 0, 0, dst);
        self.byte(0x81);
        self.modrm(3, op.imm_ext(), dst);
        self.imm32(imm as u32);
    }

    /// `add qword [base + disp], imm8` (read-modify-write).
    pub fn add_m_i8(&mut self, base: Reg, disp: i32, imm: i8) {
        self.rex(true, 0, 0, base);
        self.byte(0x83);
        self.mem(0, base, disp);
        self.byte(imm as u8);
    }

    /// `cmp dst, imm32` (sign-extended, 64-bit) — alias via alu_ri.
    pub fn cmp_ri(&mut self, dst: Reg, imm: i32) {
        self.alu_ri(AluKind::Cmp, dst, imm);
    }

    /// `test dst, src` (64-bit).
    pub fn test_rr(&mut self, dst: Reg, src: Reg) {
        self.rex(true, src, 0, dst);
        self.byte(0x85);
        self.modrm(3, src, dst);
    }

    /// `setcc dst8`.
    pub fn setcc(&mut self, cc: u8, dst: Reg) {
        let v = 0x40u8 | ((dst >> 3) & 1);
        if v != 0x40 || (4..8).contains(&dst) {
            self.byte(v);
        }
        self.byte(0x0F);
        self.byte(0x90 + cc);
        self.modrm(3, 0, dst);
    }

    // ---- shifts ----

    /// `shift dst, imm8` (64-bit).
    pub fn shift_ri(&mut self, kind: ShiftKind, dst: Reg, imm: u8) {
        self.rex(true, 0, 0, dst);
        self.byte(0xC1);
        self.modrm(3, kind.ext(), dst);
        self.byte(imm);
    }

    /// `shift dst32, imm8` (32-bit; zero-extends dst).
    pub fn shift32_ri(&mut self, kind: ShiftKind, dst: Reg, imm: u8) {
        self.rex(false, 0, 0, dst);
        self.byte(0xC1);
        self.modrm(3, kind.ext(), dst);
        self.byte(imm);
    }

    /// `shift dst, cl` (64-bit; hardware masks the count to 6 bits, which
    /// matches RV64 shift semantics exactly).
    pub fn shift_cl(&mut self, kind: ShiftKind, dst: Reg) {
        self.rex(true, 0, 0, dst);
        self.byte(0xD3);
        self.modrm(3, kind.ext(), dst);
    }

    /// `shift dst32, cl` (32-bit; hardware masks to 5 bits = RV32 word op).
    pub fn shift32_cl(&mut self, kind: ShiftKind, dst: Reg) {
        self.rex(false, 0, 0, dst);
        self.byte(0xD3);
        self.modrm(3, kind.ext(), dst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn emit(f: impl FnOnce(&mut Asm)) -> Vec<u8> {
        let mut a = Asm::new();
        f(&mut a);
        a.code
    }

    #[test]
    fn textbook_encodings() {
        assert_eq!(emit(|a| a.push_r(RBX)), [0x53]);
        assert_eq!(emit(|a| a.push_r(R12)), [0x41, 0x54]);
        assert_eq!(emit(|a| a.pop_r(R14)), [0x41, 0x5E]);
        assert_eq!(emit(|a| a.ret()), [0xC3]);
        // mov rax, rdi
        assert_eq!(emit(|a| a.mov_rr(RAX, RDI)), [0x48, 0x89, 0xF8]);
        // mov rax, [rbx+0x10]
        assert_eq!(emit(|a| a.mov_rm(RAX, RBX, 0x10)), [0x48, 0x8B, 0x43, 0x10]);
        // mov [rbp+8], rax
        assert_eq!(emit(|a| a.mov_mr(RBP, 8, RAX)), [0x48, 0x89, 0x45, 0x08]);
        // add rax, rcx
        assert_eq!(emit(|a| a.alu_rr(AluKind::Add, RAX, RCX)), [0x48, 0x01, 0xC8]);
        // shl rax, 3
        assert_eq!(
            emit(|a| a.shift_ri(ShiftKind::Shl, RAX, 3)),
            [0x48, 0xC1, 0xE0, 0x03]
        );
        // sar rax, cl
        assert_eq!(emit(|a| a.shift_cl(ShiftKind::Sar, RAX)), [0x48, 0xD3, 0xF8]);
        // cmp rax, rcx
        assert_eq!(emit(|a| a.alu_rr(AluKind::Cmp, RAX, RCX)), [0x48, 0x39, 0xC8]);
        // sete al
        assert_eq!(emit(|a| a.setcc(CC_E, RAX)), [0x0F, 0x94, 0xC0]);
        // movzx eax, al
        assert_eq!(emit(|a| a.movzx8_rr(RAX, RAX)), [0x0F, 0xB6, 0xC0]);
        // movsxd rax, eax
        assert_eq!(emit(|a| a.movsxd_rr(RAX, RAX)), [0x48, 0x63, 0xC0]);
        // call rax
        assert_eq!(emit(|a| a.call_r(RAX)), [0xFF, 0xD0]);
        // mov rsi, [r8 + rdx*8]
        assert_eq!(
            emit(|a| a.mov_rm_sib8(RSI, R8, RDX)),
            [0x49, 0x8B, 0x34, 0xD0]
        );
    }

    #[test]
    fn rbp_r13_base_always_has_displacement() {
        // [rbp+0] must encode as disp8=0, not the rip-relative md=0 form.
        assert_eq!(emit(|a| a.mov_rm(RAX, RBP, 0)), [0x48, 0x8B, 0x45, 0x00]);
        assert_eq!(
            emit(|a| a.mov_rm(RAX, R13, 0)),
            [0x49, 0x8B, 0x45, 0x00]
        );
        // [r13 + rdx*8] needs the SIB + disp8 form too.
        assert_eq!(
            emit(|a| a.mov_rm_sib8(RAX, R13, RDX)),
            [0x49, 0x8B, 0x44, 0xD5, 0x00]
        );
    }

    #[test]
    fn rsp_r12_base_needs_sib() {
        // mov rax, [rsp+8] = 48 8B 44 24 08
        assert_eq!(
            emit(|a| a.mov_rm(RAX, RSP, 8)),
            [0x48, 0x8B, 0x44, 0x24, 0x08]
        );
        // mov rax, [r12] = 49 8B 04 24
        assert_eq!(emit(|a| a.mov_rm(RAX, R12, 0)), [0x49, 0x8B, 0x04, 0x24]);
    }

    #[test]
    fn byte_stores_use_rex_for_sil_dil() {
        // mov [rbx], sil needs REX (40 88 33); without it this would be dh.
        assert_eq!(emit(|a| a.mov8_mr(RBX, 0, RSI)), [0x40, 0x88, 0x33]);
        // mov [rbx], cl has no REX (88 0B).
        assert_eq!(emit(|a| a.mov8_mr(RBX, 0, RCX)), [0x88, 0x0B]);
    }

    #[test]
    fn imm_and_disp_sizing() {
        // mov rax, imm64
        assert_eq!(
            emit(|a| a.mov_ri64(RAX, 0x1122334455667788)),
            [0x48, 0xB8, 0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11]
        );
        // mov rax, -1 via C7 /0 (sign-extended imm32)
        assert_eq!(
            emit(|a| a.mov_ri32s(RAX, -1)),
            [0x48, 0xC7, 0xC0, 0xFF, 0xFF, 0xFF, 0xFF]
        );
        // mov eax, 5 (zero-extends)
        assert_eq!(emit(|a| a.mov32_ri(RAX, 5)), [0xB8, 0x05, 0x00, 0x00, 0x00]);
        // mov_imm picks the right form
        assert_eq!(emit(|a| a.mov_imm(RAX, 5)), emit(|a| a.mov32_ri(RAX, 5)));
        assert_eq!(
            emit(|a| a.mov_imm(RAX, u64::MAX)),
            emit(|a| a.mov_ri32s(RAX, -1))
        );
        assert_eq!(
            emit(|a| a.mov_imm(RAX, 0x8000_0000)),
            emit(|a| a.mov32_ri(RAX, 0x8000_0000))
        );
        // large disp32
        assert_eq!(
            emit(|a| a.mov_rm(RAX, RBX, 0x1000)),
            [0x48, 0x8B, 0x83, 0x00, 0x10, 0x00, 0x00]
        );
        // add qword [rbx+0x18], 1
        assert_eq!(
            emit(|a| a.add_m_i8(RBX, 0x18, 1)),
            [0x48, 0x83, 0x43, 0x18, 0x01]
        );
    }

    #[test]
    fn jump_patching() {
        let mut a = Asm::new();
        let j = a.jmp_rel32();
        a.mov_rr(RAX, RCX); // 3 bytes we jump over
        let target = a.len();
        a.ret();
        a.patch_rel32(j, target);
        // E9 rel32 where rel32 = target - (j + 4) = 8 - 5 = 3
        assert_eq!(a.code[0], 0xE9);
        assert_eq!(&a.code[1..5], &3i32.to_le_bytes());

        let mut b = Asm::new();
        let jc = b.jcc_rel32(CC_NE);
        let t = b.len();
        b.patch_rel32(jc, t);
        assert_eq!(&b.code[..2], &[0x0F, 0x85]);
        assert_eq!(&b.code[2..6], &0i32.to_le_bytes());
    }

    #[test]
    fn rel32_for_absolute_addresses() {
        // field at 0x1000, target 0x2000: rel = 0x2000 - 0x1004
        assert_eq!(Asm::rel32_for(0x1000, 0x2000), 0xFFC);
        // backwards
        assert_eq!(Asm::rel32_for(0x2000, 0x1000), -(0x1004i32));
    }
}
