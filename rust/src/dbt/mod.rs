//! Binary translation layer: basic-block micro-op translation with
//! pipeline-model hooks, per-hart code caches, and block chaining
//! (paper §3.1-§3.2, Figure 1).

pub mod block;
pub mod cache;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub mod codegen;
pub mod compiler;
#[cfg(all(target_arch = "x86_64", target_os = "linux"))]
pub mod exec_buf;
pub mod seed;
pub mod x86;

pub use block::{Block, BlockId, ChainLink, CrossPageStub, Step, Term, TermKind, NO_CHAIN};
pub use cache::CodeCache;
pub use compiler::{translate, DbtCompiler, FetchProbe, MAX_BLOCK_INSTS};
pub use seed::{CodeSeed, SeedBlock};

/// Which backend executes translated blocks.
///
/// `Microop` walks the translated `Step` sequence in the Rust dispatch
/// loop; `Native` additionally compiles blocks to x86-64 host code
/// (falling back to the micro-op path per block / per step class). The
/// two are architecturally bit-identical — counters included — by
/// construction; see DESIGN.md §11.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    #[default]
    Microop,
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "microop" => Some(Backend::Microop),
            "native" => Some(Backend::Native),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Backend::Microop => "microop",
            Backend::Native => "native",
        }
    }
}

/// Is the native backend usable on this host? Requires an x86-64 Linux
/// build *and* a passing runtime self-check of the emitted ALU code
/// (cached after the first call). Everywhere else this is a compile-time
/// `false`, keeping the micro-op path the only option.
pub fn native_available() -> bool {
    #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
    {
        use std::sync::OnceLock;
        static CHECK: OnceLock<bool> = OnceLock::new();
        *CHECK.get_or_init(codegen::self_check)
    }
    #[cfg(not(all(target_arch = "x86_64", target_os = "linux")))]
    {
        false
    }
}

#[cfg(test)]
mod backend_tests {
    use super::Backend;

    #[test]
    fn backend_parse_roundtrip() {
        assert_eq!(Backend::parse("microop"), Some(Backend::Microop));
        assert_eq!(Backend::parse("native"), Some(Backend::Native));
        assert_eq!(Backend::parse("jit"), None);
        assert_eq!(Backend::default(), Backend::Microop);
        assert_eq!(Backend::Native.as_str(), "native");
    }

    #[cfg(all(target_arch = "x86_64", target_os = "linux"))]
    #[test]
    fn native_is_available_on_x86_64_linux() {
        assert!(super::native_available());
    }
}
