//! Binary translation layer: basic-block micro-op translation with
//! pipeline-model hooks, per-hart code caches, and block chaining
//! (paper §3.1-§3.2, Figure 1).

pub mod block;
pub mod cache;
pub mod compiler;

pub use block::{Block, BlockId, ChainLink, CrossPageStub, Step, Term, TermKind, NO_CHAIN};
pub use cache::CodeCache;
pub use compiler::{translate, DbtCompiler, FetchProbe, MAX_BLOCK_INSTS};
