//! Typed wrappers around the two analytics artifacts:
//! `cache_sim.hlo.txt` (exact-LRU set-associative cache over a trace chunk)
//! and `bpred.hlo.txt` (2-bit bimodal predictor over a branch chunk).
//!
//! The artifact shapes are fixed at AOT time (see python/compile/aot.py and
//! artifacts/meta.json): chunk length `T`, geometry (S sets × W ways,
//! 2^B predictor entries). Shorter chunks are padded with a sentinel that
//! the models ignore.
//!
//! Without the `xla-runtime` feature the `Xla*Sim` types are stubs whose
//! `load` fails with a descriptive error; callers check
//! [`crate::runtime::xla_available`] first.

use super::{rt_err, Result};
use crate::analytics::trace::{BranchRecord, MemRecord};
use std::path::Path;

/// Sentinel line/pc value for padding (ignored by the models).
pub const PAD: i64 = -1;

/// Age sentinel marking an invalid way — must match
/// `python/compile/kernels/cache_tags.py::INVALID_AGE`.
pub const INVALID_AGE: i32 = 1 << 30;

/// Geometry + chunk length metadata, mirrored from artifacts/meta.json.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AnalyticsMeta {
    pub chunk: usize,
    pub sets: usize,
    pub ways: usize,
    pub line_shift: u32,
    pub bpred_entries: usize,
}

impl AnalyticsMeta {
    /// Parse the tiny flat JSON written by aot.py (no JSON crate offline —
    /// the format is `{"key": value, ...}` with integer values only).
    pub fn parse(text: &str) -> Result<AnalyticsMeta> {
        let get = |key: &str| -> Result<usize> {
            let pat = format!("\"{}\":", key);
            let at = text.find(&pat).ok_or_else(|| rt_err(format!("meta.json missing {}", key)))?;
            let rest = &text[at + pat.len()..];
            let num: String = rest
                .chars()
                .skip_while(|c| c.is_whitespace())
                .take_while(|c| c.is_ascii_digit())
                .collect();
            num.parse::<usize>().map_err(|_| rt_err(format!("bad value for {}", key)))
        };
        Ok(AnalyticsMeta {
            chunk: get("chunk")?,
            sets: get("sets")?,
            ways: get("ways")?,
            line_shift: get("line_shift")? as u32,
            bpred_entries: get("bpred_entries")?,
        })
    }

    pub fn load(dir: &Path) -> Result<AnalyticsMeta> {
        let text = std::fs::read_to_string(dir.join("meta.json")).map_err(|e| {
            rt_err(format!("reading {}/meta.json — run `make artifacts`: {e}", dir.display()))
        })?;
        Self::parse(&text)
    }
}

/// Exact-LRU cache simulation offloaded to XLA.
///
/// State layout (carried across chunks as XLA literals):
///   tags: i64[S, W]   (-1 = invalid)
///   ages: i32[S, W]
/// Chunk input: lines i64[T] (paddr >> line_shift; PAD to skip).
/// Output tuple: (tags', ages', hits i64, processed i64).
#[cfg(feature = "xla-runtime")]
pub struct XlaCacheSim {
    exe: super::XlaExe,
    pub meta: AnalyticsMeta,
    tags: xla::Literal,
    ages: xla::Literal,
    pub accesses: u64,
    pub hits: u64,
}

#[cfg(feature = "xla-runtime")]
impl XlaCacheSim {
    pub fn load(dir: &Path) -> Result<XlaCacheSim> {
        let meta = AnalyticsMeta::load(dir)?;
        let exe = super::XlaExe::load(&dir.join("cache_sim.hlo.txt"))?;
        let (s, w) = (meta.sets, meta.ways);
        let tags = xla::Literal::vec1(&vec![PAD; s * w])
            .reshape(&[s as i64, w as i64])
            .map_err(|e| rt_err(format!("reshaping tags: {e}")))?;
        let ages = xla::Literal::vec1(&vec![INVALID_AGE; s * w])
            .reshape(&[s as i64, w as i64])
            .map_err(|e| rt_err(format!("reshaping ages: {e}")))?;
        Ok(XlaCacheSim { exe, meta, tags, ages, accesses: 0, hits: 0 })
    }

    /// Replay one chunk of records (≤ meta.chunk); returns hits in chunk.
    pub fn run_chunk(&mut self, records: &[MemRecord]) -> Result<u64> {
        if records.len() > self.meta.chunk {
            return Err(rt_err(format!(
                "chunk too large: {} > {}",
                records.len(),
                self.meta.chunk
            )));
        }
        let mut lines = vec![PAD; self.meta.chunk];
        for (i, r) in records.iter().enumerate() {
            lines[i] = (r.paddr >> self.meta.line_shift) as i64;
        }
        let input = xla::Literal::vec1(&lines);
        let out = self.exe.run(&[
            std::mem::replace(&mut self.tags, xla::Literal::scalar(0i64)),
            std::mem::replace(&mut self.ages, xla::Literal::scalar(0i64)),
            input,
        ])?;
        let mut out = out.into_iter();
        self.tags = out.next().ok_or_else(|| rt_err("missing tags output"))?;
        self.ages = out.next().ok_or_else(|| rt_err("missing ages output"))?;
        let hits: i64 = out
            .next()
            .ok_or_else(|| rt_err("missing hits output"))?
            .get_first_element()
            .map_err(|e| rt_err(format!("reading hits: {e}")))?;
        self.accesses += records.len() as u64;
        self.hits += hits as u64;
        Ok(hits as u64)
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

/// Bimodal branch predictor offloaded to XLA.
///
/// State: counters i32[E]. Chunk input: idx i64[T] (PAD to skip),
/// taken i32[T]. Output: (counters', correct i64).
#[cfg(feature = "xla-runtime")]
pub struct XlaBpredSim {
    exe: super::XlaExe,
    pub meta: AnalyticsMeta,
    counters: xla::Literal,
    pub predictions: u64,
    pub correct: u64,
}

#[cfg(feature = "xla-runtime")]
impl XlaBpredSim {
    pub fn load(dir: &Path) -> Result<XlaBpredSim> {
        let meta = AnalyticsMeta::load(dir)?;
        let exe = super::XlaExe::load(&dir.join("bpred.hlo.txt"))?;
        let counters = xla::Literal::vec1(&vec![1i32; meta.bpred_entries]);
        Ok(XlaBpredSim { exe, meta, counters, predictions: 0, correct: 0 })
    }

    pub fn run_chunk(&mut self, records: &[BranchRecord]) -> Result<u64> {
        if records.len() > self.meta.chunk {
            return Err(rt_err(format!(
                "chunk too large: {} > {}",
                records.len(),
                self.meta.chunk
            )));
        }
        let mut idx = vec![PAD; self.meta.chunk];
        let mut taken = vec![0i32; self.meta.chunk];
        for (i, r) in records.iter().enumerate() {
            idx[i] = ((r.pc >> 1) as usize & (self.meta.bpred_entries - 1)) as i64;
            taken[i] = r.taken as i32;
        }
        let out = self.exe.run(&[
            std::mem::replace(&mut self.counters, xla::Literal::scalar(0i32)),
            xla::Literal::vec1(&idx),
            xla::Literal::vec1(&taken),
        ])?;
        let mut out = out.into_iter();
        self.counters = out.next().ok_or_else(|| rt_err("missing counters output"))?;
        let correct: i64 = out
            .next()
            .ok_or_else(|| rt_err("missing correct output"))?
            .get_first_element()
            .map_err(|e| rt_err(format!("reading correct: {e}")))?;
        self.predictions += records.len() as u64;
        self.correct += correct as u64;
        Ok(correct as u64)
    }

    pub fn accuracy(&self) -> f64 {
        if self.predictions == 0 {
            0.0
        } else {
            self.correct as f64 / self.predictions as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Dependency-free stubs (default build): same shape, `load` always fails.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "xla-runtime"))]
const UNAVAILABLE: &str =
    "PJRT/XLA runtime not compiled in (rebuild with --features xla-runtime)";

/// Stub standing in for the XLA-offloaded cache simulation when the crate
/// is built without `xla-runtime`.
#[cfg(not(feature = "xla-runtime"))]
pub struct XlaCacheSim {
    pub meta: AnalyticsMeta,
    pub accesses: u64,
    pub hits: u64,
}

#[cfg(not(feature = "xla-runtime"))]
impl XlaCacheSim {
    pub fn load(_dir: &Path) -> Result<XlaCacheSim> {
        Err(rt_err(UNAVAILABLE))
    }

    pub fn run_chunk(&mut self, _records: &[MemRecord]) -> Result<u64> {
        Err(rt_err(UNAVAILABLE))
    }

    pub fn hit_rate(&self) -> f64 {
        0.0
    }
}

/// Stub standing in for the XLA-offloaded branch predictor when the crate
/// is built without `xla-runtime`.
#[cfg(not(feature = "xla-runtime"))]
pub struct XlaBpredSim {
    pub meta: AnalyticsMeta,
    pub predictions: u64,
    pub correct: u64,
}

#[cfg(not(feature = "xla-runtime"))]
impl XlaBpredSim {
    pub fn load(_dir: &Path) -> Result<XlaBpredSim> {
        Err(rt_err(UNAVAILABLE))
    }

    pub fn run_chunk(&mut self, _records: &[BranchRecord]) -> Result<u64> {
        Err(rt_err(UNAVAILABLE))
    }

    pub fn accuracy(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse() {
        let m = AnalyticsMeta::parse(
            r#"{"chunk": 4096, "sets": 64, "ways": 4, "line_shift": 6, "bpred_entries": 1024}"#,
        )
        .unwrap();
        assert_eq!(
            m,
            AnalyticsMeta { chunk: 4096, sets: 64, ways: 4, line_shift: 6, bpred_entries: 1024 }
        );
    }

    #[test]
    fn meta_parse_missing_key_fails() {
        assert!(AnalyticsMeta::parse(r#"{"chunk": 10}"#).is_err());
    }

    #[cfg(not(feature = "xla-runtime"))]
    #[test]
    fn stubs_report_unavailable() {
        assert!(!crate::runtime::xla_available());
        assert!(XlaCacheSim::load(Path::new(".")).is_err());
        assert!(XlaBpredSim::load(Path::new(".")).is_err());
    }
}
