//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from Rust.
//!
//! Python never runs at simulation time: `make artifacts` lowers the
//! JAX/Pallas analytics models to HLO *text* once; this module compiles
//! them with the XLA CPU PJRT client at startup and invokes them on trace
//! chunks. (HLO text — not serialized protos — is the interchange format;
//! see DESIGN.md §5.)
//!
//! The PJRT path needs the `xla` and `anyhow` crates plus a libxla
//! install, none of which are available offline. It is therefore gated
//! behind the `xla-runtime` cargo feature; the default build ships
//! dependency-free stubs whose `load` constructors report the runtime as
//! unavailable, so every consumer (tests, examples) skips cleanly.

pub mod analytics_exe;

/// Error type for the runtime layer (dependency-free `anyhow` stand-in).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Shorthand constructor used across the runtime layer.
pub fn rt_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// Was the crate built with the PJRT/XLA runtime? Consumers (the
/// analytics-integration tests, the trace-analytics example) check this
/// before attempting to load artifacts.
pub const XLA_AVAILABLE: bool = cfg!(feature = "xla-runtime");

pub fn xla_available() -> bool {
    XLA_AVAILABLE
}

/// A compiled XLA executable with its PJRT client.
#[cfg(feature = "xla-runtime")]
pub struct XlaExe {
    pub client: xla::PjRtClient,
    pub exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla-runtime")]
impl XlaExe {
    /// Load an HLO-text artifact and compile it on the CPU PJRT client.
    pub fn load(path: &std::path::Path) -> Result<XlaExe> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| rt_err(format!("creating PJRT CPU client: {e}")))?;
        Self::load_with_client(client, path)
    }

    pub fn load_with_client(client: xla::PjRtClient, path: &std::path::Path) -> Result<XlaExe> {
        let text_path = path.to_str().ok_or_else(|| rt_err("artifact path not UTF-8"))?;
        let proto = xla::HloModuleProto::from_text_file(text_path)
            .map_err(|e| rt_err(format!("parsing HLO text {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| rt_err(format!("compiling HLO on PJRT CPU: {e}")))?;
        Ok(XlaExe { client, exe })
    }

    /// Execute with literal inputs; returns the flattened output tuple
    /// (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| rt_err(format!("executing: {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err(format!("fetching result: {e}")))?;
        out.to_tuple().map_err(|e| rt_err(format!("untupling result: {e}")))
    }
}

/// Default artifacts directory: `$R2VM_ARTIFACTS` or the nearest
/// `artifacts/` directory walking up from the CWD.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("R2VM_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
