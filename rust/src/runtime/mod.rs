//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from Rust.
//!
//! Python never runs at simulation time: `make artifacts` lowers the
//! JAX/Pallas analytics models to HLO *text* once; this module compiles
//! them with the XLA CPU PJRT client at startup and invokes them on trace
//! chunks. (HLO text — not serialized protos — is the interchange format;
//! see DESIGN.md §5.)

pub mod analytics_exe;

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled XLA executable with its PJRT client.
pub struct XlaExe {
    pub client: xla::PjRtClient,
    pub exe: xla::PjRtLoadedExecutable,
}

impl XlaExe {
    /// Load an HLO-text artifact and compile it on the CPU PJRT client.
    pub fn load(path: &Path) -> Result<XlaExe> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Self::load_with_client(client, path)
    }

    pub fn load_with_client(client: xla::PjRtClient, path: &Path) -> Result<XlaExe> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO on PJRT CPU")?;
        Ok(XlaExe { client, exe })
    }

    /// Execute with literal inputs; returns the flattened output tuple
    /// (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let out = result[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }
}

/// Default artifacts directory: `$R2VM_ARTIFACTS` or the nearest
/// `artifacts/` directory walking up from the CWD.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("R2VM_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
