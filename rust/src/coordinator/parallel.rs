//! Functional-parallel execution (paper §3.5): with the atomic pipeline
//! and atomic memory model the simulator behaves like QEMU — every hart
//! runs in its own host thread over shared guest DRAM, with host atomics
//! backing AMO/LR/SC. This is the fastest mode (Figure 5's ">300 MIPS per
//! core" bar) and is also used to fast-forward boot/preparation phases
//! before handing the guest off to a cycle-level engine.
//!
//! [`ParallelEngine`] implements [`ExecutionEngine`]: between `run` calls
//! the hart states live on the engine, and each `run` spawns one thread
//! per hart, seeds it with that hart's state, and collects the state back
//! at the join. That makes the engine suspendable — `suspend` produces a
//! [`SystemSnapshot`] the coordinator can warm-start the lockstep or
//! interpreter engine from (the fast-forward → measure hand-off).
//!
//! Deviations from the lockstep engine (documented in DESIGN.md §6): each
//! thread owns a private `System` (device state is per-thread, so
//! cross-hart IPIs are only folded in at hand-off/join points; guest
//! workloads synchronise through shared memory, as the PARSEC-style
//! benchmarks do).

use super::config::SimConfig;
use crate::asm::Image;
use crate::engine::{EngineStats, ExecutionEngine, ExitReason};
use crate::fiber::FiberEngine;
use crate::mem::{AtomicModel, PhysMem, DRAM_BASE};
use crate::sys::{EcallMode, Hart, System, SystemSnapshot};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The multi-threaded functional engine (one host thread per hart).
pub struct ParallelEngine {
    num_harts: usize,
    /// Per-hart pipeline-model names: the guest can retarget a single
    /// hart's model via SIMCTRL (§3.5), and the choice must survive
    /// spawn/join rounds.
    pipelines: Vec<String>,
    simctrl_state: u64,
    phys: Arc<PhysMem>,
    harts: Vec<Hart>,
    ipi: Vec<u64>,
    msip: Vec<bool>,
    mtimecmp: Vec<u64>,
    console: Vec<u8>,
    exit: Option<u64>,
    ecall_mode: EcallMode,
    brk: u64,
    mmap_top: u64,
    /// Trace capture handed off from a previous engine stage; parked here
    /// untouched (parallel threads have per-thread device state and do
    /// not record) and returned by `suspend` so a later cycle-level stage
    /// keeps the earlier records.
    trace: Option<crate::analytics::trace::TraceCapture>,
    stats: EngineStats,
    switch_request: Option<u64>,
}

impl ParallelEngine {
    /// Boot a fresh guest from a flat image.
    pub fn from_image(cfg: &SimConfig, image: &Image) -> ParallelEngine {
        let phys = Arc::new(PhysMem::new(DRAM_BASE, cfg.dram_bytes));
        phys.load_image(image.base, &image.bytes);
        let mut eng = ParallelEngine::hollow(cfg, phys);
        eng.harts = (0..cfg.harts)
            .map(|h| {
                let mut hart = Hart::new(h);
                hart.pc = image.entry;
                hart
            })
            .collect();
        eng
    }

    /// Warm-start from a snapshot handed off by another engine.
    pub fn from_snapshot(cfg: &SimConfig, snapshot: SystemSnapshot) -> ParallelEngine {
        let mut eng = ParallelEngine::hollow(cfg, Arc::clone(&snapshot.phys));
        ExecutionEngine::resume(&mut eng, snapshot);
        eng
    }

    /// Engine shell without hart state (filled by from_image / resume).
    fn hollow(cfg: &SimConfig, phys: Arc<PhysMem>) -> ParallelEngine {
        let size = phys.size();
        ParallelEngine {
            num_harts: cfg.harts,
            pipelines: vec![cfg.pipeline.clone(); cfg.harts],
            simctrl_state: super::simctrl_encoding_full(
                super::EngineMode::Parallel,
                &cfg.pipeline,
                &cfg.memory,
                cfg.line_shift,
            ),
            phys,
            harts: Vec::new(),
            ipi: vec![0; cfg.harts],
            msip: vec![false; cfg.harts],
            mtimecmp: vec![u64::MAX; cfg.harts],
            console: Vec::new(),
            exit: None,
            ecall_mode: EcallMode::Sbi,
            brk: crate::sys::default_brk(size),
            mmap_top: crate::sys::default_mmap_top(size),
            trace: None,
            stats: EngineStats::default(),
            switch_request: None,
        }
    }

    /// One run stage: spawn a thread per hart, seed it with the hart's
    /// carried state, and join all threads, merging state back. `budget`
    /// is a per-hart instruction allowance (the threads are independent,
    /// so a global retired-instruction budget has no meaningful total
    /// order — documented in DESIGN.md §6). When every thread parks in
    /// WFI but the join-time merge collected deliverable wake sources
    /// (cross-hart IPIs / CLINT writes), the spawn/join round repeats so
    /// the seeds reach their targets; a round that changes nothing ends
    /// the stage (each re-seeded hart may retire up to `budget` more
    /// instructions in its round).
    fn run_stage(&mut self, budget: u64) -> ExitReason {
        if let Some(code) = self.exit {
            return ExitReason::Exited(code);
        }
        if let Some(value) = self.switch_request {
            return ExitReason::SwitchRequest(value);
        }
        if budget == 0 {
            return ExitReason::StepLimit;
        }
        let mut prev_wake_sig: Option<(Vec<u64>, Vec<bool>, Vec<u64>)> = None;
        loop {
            match self.run_round(budget) {
                ExitReason::Deadlock => {
                    // The merge may have just collected a wake source for
                    // a sleeping hart; retry while re-seeding can still
                    // change something (IPI seeds are consumed on
                    // delivery, so this converges).
                    let wake_possible = (0..self.num_harts).any(|t| {
                        self.harts[t].wfi
                            && !self.harts[t].halted
                            && (self.ipi[t] != 0
                                || self.msip[t]
                                || self.mtimecmp[t] != u64::MAX)
                    });
                    let sig =
                        (self.ipi.clone(), self.msip.clone(), self.mtimecmp.clone());
                    if !wake_possible || prev_wake_sig.as_ref() == Some(&sig) {
                        return ExitReason::Deadlock;
                    }
                    prev_wake_sig = Some(sig);
                }
                other => return other,
            }
        }
    }

    /// One spawn/join round of a stage.
    fn run_round(&mut self, budget: u64) -> ExitReason {
        let shared_exit = Arc::new(AtomicU64::new(u64::MAX));
        let shared_switch = Arc::new(AtomicU64::new(u64::MAX));

        let seed_simctrl = self.simctrl_state;
        let handles: Vec<_> = (0..self.num_harts)
            .map(|h| {
                let phys = Arc::clone(&self.phys);
                let shared_exit = Arc::clone(&shared_exit);
                let shared_switch = Arc::clone(&shared_switch);
                let pipeline = self.pipelines[h].clone();
                let num_harts = self.num_harts;
                let hart = std::mem::replace(&mut self.harts[h], Hart::new(h));
                let limit = hart.instret.saturating_add(budget);
                let ipi_seed = self.ipi[h];
                let msip_seed = self.msip[h];
                let mtimecmp_seed = self.mtimecmp[h];
                let simctrl_state = self.simctrl_state;
                let ecall_mode = self.ecall_mode;
                let brk = self.brk;
                let mmap_top = self.mmap_top;
                std::thread::spawn(move || {
                    let mut sys =
                        System::with_shared_phys(num_harts, phys, Box::new(AtomicModel));
                    sys.parallel = true;
                    sys.engine_code = crate::isa::csr::SIMCTRL_ENGINE_PARALLEL;
                    sys.shared_exit = Some(shared_exit);
                    sys.shared_switch = Some(shared_switch);
                    sys.simctrl_state = simctrl_state;
                    sys.ecall_mode = ecall_mode;
                    sys.brk = brk;
                    sys.mmap_top = mmap_top;
                    sys.ipi[h] = ipi_seed;
                    sys.bus.clint.msip[h] = msip_seed;
                    sys.bus.clint.mtimecmp[h] = mtimecmp_seed;
                    let mut eng = FiberEngine::new(sys, &pipeline);
                    eng.harts[h] = hart;
                    let exit = eng.run_single(h, limit);
                    let hart = eng.harts.swap_remove(h);
                    let console = std::mem::take(&mut eng.sys.bus.uart.output);
                    let ipi = std::mem::take(&mut eng.sys.ipi);
                    let msip = std::mem::take(&mut eng.sys.bus.clint.msip);
                    let mtimecmp = std::mem::take(&mut eng.sys.bus.clint.mtimecmp);
                    // Model-level SIMCTRL writes (engine field 0) are
                    // applied thread-locally; report the hart's final
                    // pipeline choice and SIMCTRL view so they survive
                    // the next spawn/join round.
                    let pipeline_after = eng.pipelines[h].name();
                    let simctrl_after = eng.sys.simctrl_state;
                    (
                        exit,
                        hart,
                        eng.stats,
                        console,
                        ipi,
                        msip,
                        mtimecmp,
                        eng.sys.brk,
                        eng.sys.mmap_top,
                        pipeline_after,
                        simctrl_after,
                    )
                })
            })
            .collect();

        // Join in hart order so the merge below is deterministic for a
        // given set of per-thread states. Cross-hart device writes (SBI
        // IPIs, CLINT msip/mtimecmp MMIO aimed at another hart) land in
        // the writer thread's private System; this is where they are
        // folded back together (DESIGN.md §6). For a hart's own CLINT
        // entries its thread is authoritative; for foreign entries a
        // set msip bit ORs in and a programmed (non-reset) mtimecmp
        // overwrites in hart order.
        for bits in self.ipi.iter_mut() {
            *bits = 0;
        }
        let results: Vec<_> = handles
            .into_iter()
            .map(|handle| handle.join().expect("hart thread panicked"))
            .collect();
        let mut all_deadlocked = true;
        // Pass 1: each hart's own state, for which its thread is
        // authoritative.
        for (h, (exit, _hart, stats, console, ipi, msip, mtimecmp, brk, mmap_top, pipeline, simctrl)) in
            results.iter().enumerate()
        {
            all_deadlocked &= *exit == ExitReason::Deadlock;
            self.stats.merge(stats);
            self.console.extend_from_slice(console);
            for (target, bits) in ipi.iter().enumerate() {
                self.ipi[target] |= bits;
            }
            self.msip[h] = msip[h];
            self.mtimecmp[h] = mtimecmp[h];
            // brk/mmap bump pointers only grow; keep the furthest.
            self.brk = self.brk.max(*brk);
            self.mmap_top = self.mmap_top.max(*mmap_top);
            self.pipelines[h] = (*pipeline).into();
            // A thread that changed its SIMCTRL view did so via a guest
            // write; keep it (hart order if several wrote).
            if *simctrl != seed_simctrl {
                self.simctrl_state = *simctrl;
            }
        }
        // Pass 2: foreign CLINT writes (MMIO aimed at another hart) — a
        // set msip bit ORs in, a programmed (non-reset) mtimecmp
        // overwrites in hart order.
        for (h, (_, _, _, _, _, msip, mtimecmp, _, _, _, _)) in results.iter().enumerate() {
            for target in 0..self.num_harts {
                if target == h {
                    continue;
                }
                if msip[target] {
                    self.msip[target] = true;
                }
                if mtimecmp[target] != u64::MAX {
                    self.mtimecmp[target] = mtimecmp[target];
                }
            }
        }
        for (h, (_, hart, ..)) in results.into_iter().enumerate() {
            self.harts[h] = hart;
        }

        let exited = shared_exit.load(Ordering::SeqCst);
        if exited != u64::MAX {
            self.exit = Some(exited);
            return ExitReason::Exited(exited);
        }
        let switch = shared_switch.load(Ordering::SeqCst);
        if switch != u64::MAX {
            self.switch_request = Some(switch);
            self.simctrl_state = switch;
            return ExitReason::SwitchRequest(switch);
        }
        if all_deadlocked {
            return ExitReason::Deadlock;
        }
        ExitReason::StepLimit
    }
}

impl ExecutionEngine for ParallelEngine {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn run(&mut self, budget: u64) -> ExitReason {
        self.run_stage(budget)
    }

    fn suspend(&mut self) -> SystemSnapshot {
        let mut harts = std::mem::take(&mut self.harts);
        SystemSnapshot::normalize_harts(&mut harts);
        SystemSnapshot {
            harts,
            phys: Arc::clone(&self.phys),
            ipi: self.ipi.clone(),
            msip: self.msip.clone(),
            mtimecmp: self.mtimecmp.clone(),
            console: std::mem::take(&mut self.console),
            exit: self.exit,
            ecall_mode: self.ecall_mode,
            brk: self.brk,
            mmap_top: self.mmap_top,
            // Parallel threads do not record (per-thread device state),
            // but a capture handed off from an earlier cycle-level stage
            // is preserved through this leg.
            trace: self.trace.take(),
        }
    }

    fn resume(&mut self, snapshot: SystemSnapshot) {
        assert_eq!(snapshot.harts.len(), self.num_harts, "hart count is fixed across hand-offs");
        self.phys = Arc::clone(&snapshot.phys);
        self.harts = snapshot.harts;
        self.ipi = snapshot.ipi;
        self.msip = snapshot.msip;
        self.mtimecmp = snapshot.mtimecmp;
        self.console = snapshot.console;
        self.exit = snapshot.exit;
        self.ecall_mode = snapshot.ecall_mode;
        self.brk = snapshot.brk;
        self.mmap_top = snapshot.mmap_top;
        self.trace = snapshot.trace;
    }

    fn stats(&self) -> EngineStats {
        self.stats
    }

    fn total_instret(&self) -> u64 {
        self.harts.iter().map(|h| h.instret).sum()
    }

    fn budget_progress(&self) -> u64 {
        // Budgets are per hart in this engine (see run_stage); report the
        // furthest hart so coordinator budget arithmetic matches.
        self.harts.iter().map(|h| h.instret).max().unwrap_or(0)
    }

    fn per_hart(&self) -> Vec<(u64, u64)> {
        self.harts.iter().map(|h| (h.cycle, h.instret)).collect()
    }

    fn console(&self) -> String {
        String::from_utf8_lossy(&self.console).into_owned()
    }

    fn model_stats(&self) -> Vec<(&'static str, u64)> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::super::run_image;
    use super::*;
    use crate::asm::*;
    use crate::isa::csr::CSR_MHARTID;

    #[test]
    fn parallel_amo_sum_no_lost_updates() {
        // 4 threads amoadd a shared counter; a racy non-atomic
        // implementation would lose updates.
        let mut a = Assembler::new(DRAM_BASE);
        let counter = a.new_label();
        let done = a.new_label();
        a.la(T1, counter);
        a.li(T2, 10_000);
        let loop_ = a.here();
        a.li(T0, 1);
        a.amoadd_w(ZERO, T0, T1);
        a.addi(T2, T2, -1);
        a.bnez(T2, loop_);
        a.la(T3, done);
        a.li(T4, 1);
        a.amoadd_w(ZERO, T4, T3);
        // hart 0 waits for all, reads counter, exits
        a.csrr(T0, CSR_MHARTID);
        let park = a.here();
        a.bnez(T0, park);
        let wait = a.here();
        a.lw(T4, T3, 0);
        a.slti(T5, T4, 4);
        a.bnez(T5, wait);
        a.lw(A0, T1, 0);
        a.li(A7, 93);
        a.ecall();
        a.align(8);
        a.bind(counter);
        a.d32(0);
        a.bind(done);
        a.d32(0);
        let img = a.finish();

        let mut cfg = SimConfig::default();
        cfg.harts = 4;
        cfg.pipeline = "atomic".into();
        cfg.set("mode", "parallel").unwrap();
        let report = run_image(&cfg, &img);
        assert_eq!(report.exit, ExitReason::Exited(40_000));
    }

    #[test]
    fn parallel_lrsc_spinlock() {
        // 2 threads, LR/SC lock protecting a non-atomic increment.
        let mut a = Assembler::new(DRAM_BASE);
        let lock = a.new_label();
        let counter = a.new_label();
        let done = a.new_label();
        a.la(A1, lock);
        a.la(A2, counter);
        a.li(S0, 5_000);
        let loop_ = a.here();
        let acquire = a.here();
        a.lr_w(T0, A1);
        a.bnez(T0, acquire);
        a.li(T1, 1);
        a.sc_w(T0, T1, A1);
        a.bnez(T0, acquire);
        a.lw(T2, A2, 0);
        a.addi(T2, T2, 1);
        a.sw(T2, A2, 0);
        a.fence();
        a.amoswap_w(ZERO, ZERO, A1); // release (atomic store 0)
        a.addi(S0, S0, -1);
        a.bnez(S0, loop_);
        a.la(T3, done);
        a.li(T4, 1);
        a.amoadd_w(ZERO, T4, T3);
        a.csrr(T0, CSR_MHARTID);
        let park = a.here();
        a.bnez(T0, park);
        let wait = a.here();
        a.lw(T4, T3, 0);
        a.slti(T5, T4, 2);
        a.bnez(T5, wait);
        a.lw(A0, A2, 0);
        a.li(A7, 93);
        a.ecall();
        a.align(8);
        a.bind(lock);
        a.d32(0);
        a.align(64); // counter on its own line
        a.bind(counter);
        a.d32(0);
        a.bind(done);
        a.d32(0);
        let img = a.finish();

        let mut cfg = SimConfig::default();
        cfg.harts = 2;
        cfg.pipeline = "atomic".into();
        cfg.set("mode", "parallel").unwrap();
        let report = run_image(&cfg, &img);
        assert_eq!(report.exit, ExitReason::Exited(10_000), "no lost increments under the lock");
    }

    #[test]
    fn parallel_budget_suspends_into_snapshot() {
        // A finite budget stops every hart thread; the collected snapshot
        // must carry the harts' progress so a later stage can continue.
        let mut a = Assembler::new(DRAM_BASE);
        a.li(A0, 1_000_000);
        let top = a.here();
        a.addi(A0, A0, -1);
        a.bnez(A0, top);
        a.li(A7, 93);
        a.ecall();
        let img = a.finish();

        let mut cfg = SimConfig::default();
        cfg.harts = 2;
        cfg.pipeline = "atomic".into();
        cfg.set("mode", "parallel").unwrap();
        let mut eng = ParallelEngine::from_image(&cfg, &img);
        assert_eq!(ExecutionEngine::run(&mut eng, 5_000), ExitReason::StepLimit);
        let snap = ExecutionEngine::suspend(&mut eng);
        assert_eq!(snap.harts.len(), 2);
        for hart in &snap.harts {
            assert!(hart.instret >= 5_000, "hart must have used its budget");
            assert!(hart.pc >= DRAM_BASE, "pc must be written back");
        }
    }
}
