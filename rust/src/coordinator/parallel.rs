//! Functional-parallel execution (paper §3.5): with the atomic pipeline
//! and atomic memory model the simulator behaves like QEMU — every hart
//! runs in its own host thread over shared guest DRAM, with host atomics
//! backing AMO/LR/SC. This is the fastest mode (Figure 5's ">300 MIPS per
//! core" bar) and is also used to fast-forward boot/preparation phases
//! before switching to a timing mode.
//!
//! Deviations from the lockstep engine (documented in DESIGN.md): each
//! thread owns a private `System` (device state is per-thread, so
//! cross-hart IPIs are unavailable in this mode; guest workloads
//! synchronise through shared memory, as the PARSEC-style benchmarks do).

use super::config::SimConfig;
use super::RunReport;
use crate::asm::Image;
use crate::fiber::FiberEngine;
use crate::interp::ExitReason;
use crate::mem::{AtomicModel, PhysMem, DRAM_BASE};
use crate::sys::System;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Run `image` with one host thread per hart.
pub fn run_parallel(cfg: &SimConfig, image: &Image) -> RunReport {
    let phys = Arc::new(PhysMem::new(DRAM_BASE, cfg.dram_bytes));
    phys.load_image(image.base, &image.bytes);
    let entry = image.entry;
    let shared_exit = Arc::new(AtomicU64::new(u64::MAX));

    let t0 = Instant::now();
    let handles: Vec<_> = (0..cfg.harts)
        .map(|h| {
            let phys = Arc::clone(&phys);
            let shared_exit = Arc::clone(&shared_exit);
            let pipeline = cfg.pipeline.clone();
            let max_insts = cfg.max_insts;
            let harts = cfg.harts;
            std::thread::spawn(move || {
                let mut sys = System::with_shared_phys(harts, phys, Box::new(AtomicModel));
                sys.parallel = true;
                sys.shared_exit = Some(Arc::clone(&shared_exit));
                let mut eng = FiberEngine::new(sys, &pipeline);
                eng.set_entry(entry);
                let exit = eng.run_single(h, max_insts, &shared_exit);
                let hart = &eng.harts[h];
                (exit, hart.cycle, hart.instret, eng.sys.bus.uart.output_str())
            })
        })
        .collect();

    let mut per_hart = Vec::new();
    let mut total_insts = 0;
    let mut console = String::new();
    let mut exit = ExitReason::StepLimit;
    for handle in handles {
        let (e, cycle, instret, out) = handle.join().expect("hart thread panicked");
        if let ExitReason::Exited(_) = e {
            exit = e;
        }
        per_hart.push((cycle, instret));
        total_insts += instret;
        console.push_str(&out);
    }
    let wall = t0.elapsed();
    if exit == ExitReason::StepLimit {
        let v = shared_exit.load(Ordering::SeqCst);
        if v != u64::MAX {
            exit = ExitReason::Exited(v);
        }
    }
    RunReport {
        exit,
        wall,
        total_insts,
        per_hart,
        console,
        model_stats: Vec::new(),
        engine_stats: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::*;
    use crate::isa::csr::CSR_MHARTID;

    #[test]
    fn parallel_amo_sum_no_lost_updates() {
        // 4 threads amoadd a shared counter; a racy non-atomic
        // implementation would lose updates.
        let mut a = Assembler::new(DRAM_BASE);
        let counter = a.new_label();
        let done = a.new_label();
        a.la(T1, counter);
        a.li(T2, 10_000);
        let loop_ = a.here();
        a.li(T0, 1);
        a.amoadd_w(ZERO, T0, T1);
        a.addi(T2, T2, -1);
        a.bnez(T2, loop_);
        a.la(T3, done);
        a.li(T4, 1);
        a.amoadd_w(ZERO, T4, T3);
        // hart 0 waits for all, reads counter, exits
        a.csrr(T0, CSR_MHARTID);
        let park = a.here();
        a.bnez(T0, park);
        let wait = a.here();
        a.lw(T4, T3, 0);
        a.slti(T5, T4, 4);
        a.bnez(T5, wait);
        a.lw(A0, T1, 0);
        a.li(A7, 93);
        a.ecall();
        a.align(8);
        a.bind(counter);
        a.d32(0);
        a.bind(done);
        a.d32(0);
        let img = a.finish();

        let mut cfg = SimConfig::default();
        cfg.harts = 4;
        cfg.pipeline = "atomic".into();
        cfg.set("mode", "parallel").unwrap();
        let report = run_parallel(&cfg, &img);
        assert_eq!(report.exit, ExitReason::Exited(40_000));
    }

    #[test]
    fn parallel_lrsc_spinlock() {
        // 2 threads, LR/SC lock protecting a non-atomic increment.
        let mut a = Assembler::new(DRAM_BASE);
        let lock = a.new_label();
        let counter = a.new_label();
        let done = a.new_label();
        a.la(A1, lock);
        a.la(A2, counter);
        a.li(S0, 5_000);
        let loop_ = a.here();
        let acquire = a.here();
        a.lr_w(T0, A1);
        a.bnez(T0, acquire);
        a.li(T1, 1);
        a.sc_w(T0, T1, A1);
        a.bnez(T0, acquire);
        a.lw(T2, A2, 0);
        a.addi(T2, T2, 1);
        a.sw(T2, A2, 0);
        a.fence();
        a.amoswap_w(ZERO, ZERO, A1); // release (atomic store 0)
        a.addi(S0, S0, -1);
        a.bnez(S0, loop_);
        a.la(T3, done);
        a.li(T4, 1);
        a.amoadd_w(ZERO, T4, T3);
        a.csrr(T0, CSR_MHARTID);
        let park = a.here();
        a.bnez(T0, park);
        let wait = a.here();
        a.lw(T4, T3, 0);
        a.slti(T5, T4, 2);
        a.bnez(T5, wait);
        a.lw(A0, A2, 0);
        a.li(A7, 93);
        a.ecall();
        a.align(8);
        a.bind(lock);
        a.d32(0);
        a.align(64); // counter on its own line
        a.bind(counter);
        a.d32(0);
        a.bind(done);
        a.d32(0);
        let img = a.finish();

        let mut cfg = SimConfig::default();
        cfg.harts = 2;
        cfg.pipeline = "atomic".into();
        cfg.set("mode", "parallel").unwrap();
        let report = run_parallel(&cfg, &img);
        assert_eq!(report.exit, ExitReason::Exited(10_000), "no lost increments under the lock");
    }
}
