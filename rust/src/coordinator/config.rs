//! Simulation configuration: model selection, geometry, timing, engine
//! mode. Parsed from CLI arguments (no external config-parsing crates are
//! available offline; the format is deliberately simple `key=value`).

use crate::mem::cache_model::CacheGeometry;
use crate::mem::MemTiming;

/// Which execution engine drives the simulation (Figure 5's x-axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// Naive per-cycle interpreter (gem5-like baseline).
    Interp,
    /// Single-threaded lockstep DBT (cycle-level modes).
    Lockstep,
    /// Multi-threaded functional DBT (QEMU-like; atomic models only).
    Parallel,
}

impl EngineMode {
    pub fn parse(s: &str) -> Option<EngineMode> {
        match s {
            "interp" => Some(EngineMode::Interp),
            "lockstep" => Some(EngineMode::Lockstep),
            "parallel" => Some(EngineMode::Parallel),
            _ => None,
        }
    }
}

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub harts: usize,
    pub dram_bytes: usize,
    pub pipeline: String,
    pub memory: String,
    pub mode: EngineMode,
    pub max_insts: u64,
    pub timing: MemTiming,
    pub l1_geom: CacheGeometry,
    pub l2_geom: CacheGeometry,
    /// L0 line shift (6 = 64 B lines; 12 turns L0 into a TLB, §3.5).
    pub line_shift: u32,
    /// Enable analytics trace capture with this many records.
    pub trace_capacity: usize,
    /// A1 ablation: yield per instruction.
    pub naive_yield: bool,
    /// A3 ablation: disable block chaining.
    pub no_chaining: bool,
    /// A2 ablation: bypass L0 (memory model on every access).
    pub no_l0: bool,
    /// Echo guest console output to stdout.
    pub console: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            harts: 1,
            dram_bytes: 64 << 20,
            pipeline: "simple".into(),
            memory: "atomic".into(),
            mode: EngineMode::Lockstep,
            max_insts: u64::MAX,
            timing: MemTiming::default(),
            l1_geom: CacheGeometry::default_l1(),
            l2_geom: CacheGeometry { sets: 256, ways: 8, line_shift: 6 },
            line_shift: 6,
            trace_capacity: 0,
            naive_yield: false,
            no_chaining: false,
            no_l0: false,
            console: false,
        }
    }
}

/// CLI parse error.
#[derive(Debug)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl SimConfig {
    /// Apply one `--key value` pair; returns Err on unknown keys/values.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ParseError> {
        let bad = |what: &str| ParseError(format!("invalid value for --{}: {}", what, value));
        match key {
            "harts" => self.harts = value.parse().map_err(|_| bad("harts"))?,
            "dram-mb" => {
                let mb: usize = value.parse().map_err(|_| bad("dram-mb"))?;
                self.dram_bytes = mb << 20;
            }
            "pipeline" => {
                if crate::pipeline::by_name(value).is_none() {
                    return Err(ParseError(format!(
                        "unknown pipeline model '{}' (atomic|simple|inorder)",
                        value
                    )));
                }
                self.pipeline = value.into();
            }
            "memory" => {
                if !matches!(value, "atomic" | "tlb" | "cache" | "mesi") {
                    return Err(ParseError(format!(
                        "unknown memory model '{}' (atomic|tlb|cache|mesi)",
                        value
                    )));
                }
                self.memory = value.into();
            }
            "mode" => {
                self.mode = EngineMode::parse(value)
                    .ok_or_else(|| ParseError(format!("unknown mode '{}'", value)))?;
            }
            "max-insts" => self.max_insts = value.parse().map_err(|_| bad("max-insts"))?,
            "line-bytes" => {
                let b: u64 = value.parse().map_err(|_| bad("line-bytes"))?;
                if !b.is_power_of_two() || !(4..=4096).contains(&b) {
                    return Err(bad("line-bytes"));
                }
                self.line_shift = b.trailing_zeros();
            }
            "trace" => self.trace_capacity = value.parse().map_err(|_| bad("trace"))?,
            _ => return Err(ParseError(format!("unknown option --{}", key))),
        }
        Ok(())
    }

    /// Consistency checks mirroring Table 2's constraints.
    pub fn validate(&self) -> Result<(), ParseError> {
        if self.harts == 0 || self.harts > 32 {
            return Err(ParseError("harts must be in 1..=32".into()));
        }
        if self.mode == EngineMode::Parallel && self.memory != "atomic" {
            return Err(ParseError(
                "parallel execution requires the atomic memory model (Table 2)".into(),
            ));
        }
        if self.memory == "mesi" && self.mode == EngineMode::Parallel {
            return Err(ParseError("MESI requires lockstep execution (Table 2)".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn set_and_validate() {
        let mut c = SimConfig::default();
        c.set("harts", "4").unwrap();
        c.set("pipeline", "inorder").unwrap();
        c.set("memory", "mesi").unwrap();
        c.set("line-bytes", "4096").unwrap();
        assert_eq!(c.line_shift, 12);
        c.validate().unwrap();
        assert!(c.set("pipeline", "o3").is_err());
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("line-bytes", "48").is_err());
    }

    #[test]
    fn parallel_requires_atomic() {
        let mut c = SimConfig::default();
        c.set("mode", "parallel").unwrap();
        c.set("memory", "mesi").unwrap();
        assert!(c.validate().is_err());
        c.set("memory", "atomic").unwrap();
        c.validate().unwrap();
    }
}
