//! Simulation configuration: model selection, geometry, timing, engine
//! mode. Parsed from CLI arguments (no external config-parsing crates are
//! available offline; the format is deliberately simple `key=value`).

use crate::mem::cache_model::CacheGeometry;
use crate::mem::MemTiming;

/// Which execution engine drives the simulation (Figure 5's x-axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// Naive per-cycle interpreter (gem5-like baseline).
    Interp,
    /// Single-threaded lockstep DBT (cycle-level modes).
    Lockstep,
    /// Multi-threaded functional DBT (QEMU-like; atomic models only).
    Parallel,
    /// Sharded cycle-level DBT: harts partitioned across host threads
    /// with deterministic quantum barriers (DESIGN.md §10). Quantum 1
    /// serializes into the exact single-threaded lockstep schedule.
    Sharded,
}

impl EngineMode {
    /// Every engine mode, in presentation order — the registry diagnostics
    /// (`--mode`/`--switch-to` errors, the self-tuning flag checks) derive
    /// their candidate lists from here, mirroring `pipeline::MODELS`.
    pub const MODES: [EngineMode; 4] = [
        EngineMode::Interp,
        EngineMode::Lockstep,
        EngineMode::Parallel,
        EngineMode::Sharded,
    ];

    /// `"interp|lockstep|parallel|sharded"` — for error messages.
    pub fn names() -> String {
        Self::MODES.map(|m| m.as_str()).join("|")
    }

    pub fn parse(s: &str) -> Option<EngineMode> {
        match s {
            "interp" => Some(EngineMode::Interp),
            "lockstep" => Some(EngineMode::Lockstep),
            "parallel" => Some(EngineMode::Parallel),
            "sharded" => Some(EngineMode::Sharded),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            EngineMode::Interp => "interp",
            EngineMode::Lockstep => "lockstep",
            EngineMode::Parallel => "parallel",
            EngineMode::Sharded => "sharded",
        }
    }

    /// SIMCTRL engine-field code (see `isa::csr::CSR_SIMCTRL`).
    pub fn code(self) -> u64 {
        match self {
            EngineMode::Interp => 1,
            EngineMode::Lockstep => 2,
            EngineMode::Parallel => 3,
            EngineMode::Sharded => 4,
        }
    }

    /// Inverse of [`EngineMode::code`]; 0 and invalid codes mean "keep".
    pub fn from_code(code: u64) -> Option<EngineMode> {
        match code {
            1 => Some(EngineMode::Interp),
            2 => Some(EngineMode::Lockstep),
            3 => Some(EngineMode::Parallel),
            4 => Some(EngineMode::Sharded),
            _ => None,
        }
    }
}

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub harts: usize,
    pub dram_bytes: usize,
    pub pipeline: String,
    pub memory: String,
    pub mode: EngineMode,
    pub max_insts: u64,
    pub timing: MemTiming,
    pub l1_geom: CacheGeometry,
    pub l2_geom: CacheGeometry,
    /// L0 line shift (6 = 64 B lines; 12 turns L0 into a TLB, §3.5).
    pub line_shift: u32,
    /// Sharded mode: number of host threads ("shards") the harts are
    /// partitioned across (clamped to the hart count at engine build).
    pub shards: usize,
    /// Sharded mode: barrier quantum in cycles. 1 = serialized execution,
    /// bit-identical to the single-threaded lockstep engine; larger quanta
    /// trade bounded cross-shard timing skew for parallel speed.
    pub quantum: u64,
    /// Sharded mode: enable the adaptive-quantum controller
    /// (`--adaptive-quantum`, DESIGN.md §15). The barrier leader resizes
    /// the quantum each epoch from the previous epoch's cross-shard
    /// message count — deterministic, never wall-clock-driven.
    pub adaptive_quantum: bool,
    /// Adaptive-quantum floor (`--quantum-min`); defaults to
    /// [`SimConfig::DEFAULT_QUANTUM_MIN`].
    pub quantum_min: Option<u64>,
    /// Adaptive-quantum ceiling (`--quantum-max`); defaults to
    /// [`SimConfig::DEFAULT_QUANTUM_MAX`].
    pub quantum_max: Option<u64>,
    /// Sharded mode: re-cut the hart→shard assignment from per-hart
    /// retirement rates every this many retired instructions
    /// (`--repartition-every`); 0 = static partition.
    pub repartition_every: u64,
    /// Enable analytics trace capture with this many records.
    pub trace_capacity: usize,
    /// A1 ablation: yield per instruction.
    pub naive_yield: bool,
    /// A3 ablation: disable block chaining.
    pub no_chaining: bool,
    /// Which DBT backend executes translated blocks (`--backend`).
    /// `Native` requires an x86-64 Linux host (validated eagerly).
    pub backend: crate::dbt::Backend,
    /// `--dump-native <pc>`: dump the emitted host code of the block
    /// containing this guest PC to stderr (native backend diagnostics).
    pub dump_native: Option<u64>,
    /// A2 ablation: bypass L0 (memory model on every access).
    pub no_l0: bool,
    /// Echo guest console output to stdout.
    pub console: bool,
    /// Engine hand-off budget: after this many retired instructions
    /// (per hart in parallel mode) suspend the engine and warm-start the
    /// `switch_to` target — the fast-forward → measure workflow.
    pub switch_at: Option<u64>,
    /// Hand-off target as `mode:pipeline:memory`. Also the measured
    /// configuration of a sampled run.
    pub switch_to: String,
    /// Checkpoint output path: the guest state at run end is serialized
    /// here; with `ckpt_every` set, periodic checkpoints also go to
    /// `<path>.<seq>`.
    pub ckpt_out: Option<String>,
    /// Periodic-checkpoint interval in retired instructions (per hart in
    /// parallel mode, like `switch_at`).
    pub ckpt_every: Option<u64>,
    /// Start from this checkpoint file instead of booting an image.
    pub restore: Option<String>,
    /// SMARTS-style sampling plan (`--sample n:warmup:measure[:interval]`).
    pub sample: Option<crate::sampling::SamplePlan>,
    /// `--trace-out <path>`: write the event timeline as Chrome
    /// trace-event JSON here at run end (implies `trace_events`).
    pub trace_out: Option<String>,
    /// `--stats-every <n>`: emit one NDJSON telemetry line to stderr every
    /// `n` retired instructions (0 = off).
    pub stats_every: u64,
    /// Collect per-block execution/cycle counters (the `profile`
    /// subcommand sets this; also allowed on plain runs).
    pub profile: bool,
    /// Record timeline events into the observability ring buffers.
    pub trace_events: bool,
    /// Per-observer event ring capacity (`--obs-capacity`); overflow
    /// drops the newest events and counts them, never silently.
    pub obs_capacity: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            harts: 1,
            dram_bytes: 64 << 20,
            pipeline: "simple".into(),
            memory: "atomic".into(),
            mode: EngineMode::Lockstep,
            max_insts: u64::MAX,
            timing: MemTiming::default(),
            l1_geom: CacheGeometry::default_l1(),
            l2_geom: CacheGeometry { sets: 256, ways: 8, line_shift: 6 },
            line_shift: 6,
            shards: 1,
            quantum: 1024,
            adaptive_quantum: false,
            quantum_min: None,
            quantum_max: None,
            repartition_every: 0,
            trace_capacity: 0,
            naive_yield: false,
            no_chaining: false,
            backend: crate::dbt::Backend::default(),
            dump_native: None,
            no_l0: false,
            console: false,
            switch_at: None,
            switch_to: "lockstep:inorder:mesi".into(),
            ckpt_out: None,
            ckpt_every: None,
            restore: None,
            sample: None,
            trace_out: None,
            stats_every: 0,
            profile: false,
            trace_events: false,
            obs_capacity: 1 << 16,
        }
    }
}

/// CLI parse error.
#[derive(Debug)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl SimConfig {
    /// Adaptive-quantum default floor when `--quantum-min` is not given.
    pub const DEFAULT_QUANTUM_MIN: u64 = 64;
    /// Adaptive-quantum default ceiling when `--quantum-max` is not given.
    pub const DEFAULT_QUANTUM_MAX: u64 = 16384;

    /// The `[min, max]` band the adaptive-quantum controller operates in.
    pub fn quantum_bounds(&self) -> (u64, u64) {
        (
            self.quantum_min.unwrap_or(Self::DEFAULT_QUANTUM_MIN),
            self.quantum_max.unwrap_or(Self::DEFAULT_QUANTUM_MAX),
        )
    }

    /// Apply one `--key value` pair; returns Err on unknown keys/values.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ParseError> {
        let bad = |what: &str| ParseError(format!("invalid value for --{}: {}", what, value));
        match key {
            "harts" => self.harts = value.parse().map_err(|_| bad("harts"))?,
            "dram-mb" => {
                let mb: usize = value.parse().map_err(|_| bad("dram-mb"))?;
                self.dram_bytes = mb << 20;
            }
            "pipeline" => {
                if crate::pipeline::by_name(value).is_none() {
                    return Err(ParseError(format!(
                        "unknown pipeline model '{}' ({})",
                        value,
                        crate::pipeline::model_names()
                    )));
                }
                self.pipeline = value.into();
            }
            "memory" => {
                if !crate::engine::MEMORY_MODEL_NAMES.contains(&value) {
                    return Err(ParseError(format!(
                        "unknown memory model '{}' (atomic|tlb|cache|mesi)",
                        value
                    )));
                }
                self.memory = value.into();
            }
            "mode" => {
                self.mode = EngineMode::parse(value).ok_or_else(|| {
                    ParseError(format!("unknown mode '{}' ({})", value, EngineMode::names()))
                })?;
            }
            "max-insts" => self.max_insts = value.parse().map_err(|_| bad("max-insts"))?,
            "shards" => {
                let s: usize = value.parse().map_err(|_| bad("shards"))?;
                if s == 0 {
                    return Err(bad("shards"));
                }
                self.shards = s;
            }
            "quantum" => {
                let q: u64 = value.parse().map_err(|_| bad("quantum"))?;
                if q == 0 {
                    return Err(bad("quantum"));
                }
                self.quantum = q;
            }
            "adaptive-quantum" => {
                self.adaptive_quantum = match value {
                    "true" | "on" | "1" => true,
                    "false" | "off" | "0" => false,
                    _ => return Err(bad("adaptive-quantum")),
                };
            }
            "quantum-min" => {
                let q: u64 = value.parse().map_err(|_| bad("quantum-min"))?;
                if q == 0 {
                    return Err(bad("quantum-min"));
                }
                self.quantum_min = Some(q);
            }
            "quantum-max" => {
                let q: u64 = value.parse().map_err(|_| bad("quantum-max"))?;
                if q == 0 {
                    return Err(bad("quantum-max"));
                }
                self.quantum_max = Some(q);
            }
            "repartition-every" => {
                let n: u64 = value.parse().map_err(|_| bad("repartition-every"))?;
                if n == 0 {
                    return Err(bad("repartition-every"));
                }
                self.repartition_every = n;
            }
            "line-bytes" => {
                let b: u64 = value.parse().map_err(|_| bad("line-bytes"))?;
                if !b.is_power_of_two() || !(4..=4096).contains(&b) {
                    return Err(bad("line-bytes"));
                }
                self.line_shift = b.trailing_zeros();
            }
            "backend" => {
                self.backend = crate::dbt::Backend::parse(value).ok_or_else(|| {
                    ParseError(format!("unknown backend '{}' (microop|native)", value))
                })?;
            }
            "dump-native" => {
                let pc = if let Some(hex) = value.strip_prefix("0x") {
                    u64::from_str_radix(hex, 16).map_err(|_| bad("dump-native"))
                } else {
                    value.parse().map_err(|_| bad("dump-native"))
                }?;
                self.dump_native = Some(pc);
            }
            "trace" => self.trace_capacity = value.parse().map_err(|_| bad("trace"))?,
            "switch-at" => {
                self.switch_at = Some(value.parse().map_err(|_| bad("switch-at"))?)
            }
            "switch-to" => {
                parse_switch_target(value)?; // validate eagerly for a good error
                self.switch_to = value.into();
            }
            "ckpt-out" => self.ckpt_out = Some(value.into()),
            "ckpt-every" => {
                let n: u64 = value.parse().map_err(|_| bad("ckpt-every"))?;
                if n == 0 {
                    return Err(bad("ckpt-every"));
                }
                self.ckpt_every = Some(n);
            }
            "restore" => self.restore = Some(value.into()),
            "trace-out" => {
                self.trace_out = Some(value.into());
                self.trace_events = true;
            }
            "stats-every" => self.stats_every = value.parse().map_err(|_| bad("stats-every"))?,
            "obs-capacity" => {
                let n: usize = value.parse().map_err(|_| bad("obs-capacity"))?;
                if n == 0 {
                    return Err(bad("obs-capacity"));
                }
                self.obs_capacity = n;
            }
            "sample" => {
                self.sample =
                    Some(crate::sampling::SamplePlan::parse(value).map_err(ParseError)?)
            }
            _ => return Err(ParseError(format!("unknown option --{}", key))),
        }
        Ok(())
    }

    /// Parse and validate the `switch_to` hand-off target.
    pub fn switch_target(&self) -> Result<(EngineMode, String, String), ParseError> {
        parse_switch_target(&self.switch_to)
    }

    /// Whether any observability feature is on. When false, `System.obs`
    /// stays `None` and the hot path never takes the cold obs branch.
    pub fn obs_enabled(&self) -> bool {
        self.trace_events || self.profile || self.stats_every > 0
    }

    /// Consistency checks mirroring Table 2's constraints.
    pub fn validate(&self) -> Result<(), ParseError> {
        if self.harts == 0 || self.harts > 32 {
            return Err(ParseError("harts must be in 1..=32".into()));
        }
        if self.mode == EngineMode::Parallel && self.memory != "atomic" {
            return Err(ParseError(
                "parallel execution requires the atomic memory model (Table 2)".into(),
            ));
        }
        if self.memory == "mesi" && self.mode == EngineMode::Parallel {
            return Err(ParseError("MESI requires lockstep execution (Table 2)".into()));
        }
        if self.shards > 32 {
            return Err(ParseError("shards must be in 1..=32".into()));
        }
        // Self-tuning flags only mean something under the sharded engine's
        // threaded driver — reject contradictory combinations instead of
        // silently ignoring them (the diagnostics derive candidate lists
        // from the registries, like the pipeline errors do).
        let tuning = self.adaptive_quantum
            || self.quantum_min.is_some()
            || self.quantum_max.is_some()
            || self.repartition_every > 0;
        if tuning && self.mode != EngineMode::Sharded {
            return Err(ParseError(format!(
                "--adaptive-quantum/--quantum-min/--quantum-max/--repartition-every \
                 require --mode sharded (engine modes: {}; --mode is {})",
                EngineMode::names(),
                self.mode.as_str()
            )));
        }
        if tuning && self.quantum == 1 {
            return Err(ParseError(
                "--quantum 1 is the serialized verification schedule; the adaptive \
                 controller and re-partitioning need the threaded driver (--quantum > 1)"
                    .into(),
            ));
        }
        if (self.quantum_min.is_some() || self.quantum_max.is_some()) && !self.adaptive_quantum {
            return Err(ParseError(
                "--quantum-min/--quantum-max only apply with --adaptive-quantum".into(),
            ));
        }
        let (qmin, qmax) = self.quantum_bounds();
        if self.adaptive_quantum && qmin > qmax {
            return Err(ParseError(format!(
                "--quantum-min {} exceeds --quantum-max {}",
                qmin, qmax
            )));
        }
        if self.repartition_every > 0 && self.shards < 2 {
            return Err(ParseError(
                "--repartition-every needs at least two shards to re-balance (--shards >= 2)"
                    .into(),
            ));
        }
        if self.switch_at.is_some() {
            self.switch_target()?;
        }
        if self.ckpt_every.is_some() && self.ckpt_out.is_none() {
            return Err(ParseError("--ckpt-every requires --ckpt-out".into()));
        }
        if self.backend == crate::dbt::Backend::Native && !crate::dbt::native_available() {
            return Err(ParseError(
                "--backend native requires an x86-64 Linux host (and a passing \
                 emitter self-check); use --backend microop"
                    .into(),
            ));
        }
        if self.sample.is_some() {
            // The measured windows come from the switch target; it must be
            // a cycle-counting engine.
            let (mode, _, _) = self.switch_target()?;
            if mode == EngineMode::Parallel {
                return Err(ParseError(
                    "sampling measures under the --switch-to target, which cannot be the \
                     parallel engine (it does not track cycles)"
                        .into(),
                ));
            }
            if self.mode == EngineMode::Sharded && mode != EngineMode::Sharded {
                return Err(ParseError(format!(
                    "--mode sharded with --sample measures under the sharded engine; \
                     set --switch-to sharded:<pipeline>:<memory> (target mode is {})",
                    mode.as_str()
                )));
            }
            if self.switch_at.is_some() {
                return Err(ParseError("--sample and --switch-at are mutually exclusive".into()));
            }
            if self.ckpt_out.is_some() || self.restore.is_some() {
                return Err(ParseError(
                    "--sample cannot be combined with --ckpt-out/--restore".into(),
                ));
            }
            if self.obs_enabled() {
                return Err(ParseError(
                    "--sample cannot be combined with --trace-out/--stats-every/profile \
                     (sampled windows rebuild engines outside the staged loop)"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

/// Parse a `mode:pipeline:memory` hand-off target (the `--switch-to`
/// value), enforcing Table 2's engine/model constraints.
pub fn parse_switch_target(s: &str) -> Result<(EngineMode, String, String), ParseError> {
    let parts: Vec<&str> = s.split(':').collect();
    if parts.len() != 3 {
        return Err(ParseError(format!(
            "--switch-to must be mode:pipeline:memory, got '{}'",
            s
        )));
    }
    let mode = EngineMode::parse(parts[0]).ok_or_else(|| {
        ParseError(format!("unknown switch-to mode '{}' ({})", parts[0], EngineMode::names()))
    })?;
    if crate::pipeline::by_name(parts[1]).is_none() {
        return Err(ParseError(format!(
            "unknown switch-to pipeline '{}' ({})",
            parts[1],
            crate::pipeline::model_names()
        )));
    }
    if !crate::engine::MEMORY_MODEL_NAMES.contains(&parts[2]) {
        return Err(ParseError(format!("unknown switch-to memory '{}'", parts[2])));
    }
    if mode == EngineMode::Parallel && parts[2] != "atomic" {
        return Err(ParseError(
            "switch-to parallel requires the atomic memory model (Table 2)".into(),
        ));
    }
    Ok((mode, parts[1].into(), parts[2].into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn set_and_validate() {
        let mut c = SimConfig::default();
        c.set("harts", "4").unwrap();
        c.set("pipeline", "inorder").unwrap();
        c.set("memory", "mesi").unwrap();
        c.set("line-bytes", "4096").unwrap();
        assert_eq!(c.line_shift, 12);
        c.validate().unwrap();
        // "o3" is a registered model; aliases resolve too (registry-driven).
        c.set("pipeline", "o3").unwrap();
        c.set("pipeline", "out-of-order").unwrap();
        c.validate().unwrap();
        let err = c.set("pipeline", "warp").unwrap_err();
        assert!(err.0.contains("atomic|simple|inorder|o3"), "registry-derived list: {}", err.0);
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("line-bytes", "48").is_err());
    }

    #[test]
    fn switch_flags_parse_and_validate() {
        let mut c = SimConfig::default();
        c.set("switch-at", "100000").unwrap();
        c.validate().unwrap();
        assert_eq!(c.switch_at, Some(100_000));
        assert_eq!(
            c.switch_target().unwrap(),
            (EngineMode::Lockstep, "inorder".into(), "mesi".into())
        );
        c.set("switch-to", "interp:simple:tlb").unwrap();
        assert_eq!(
            c.switch_target().unwrap(),
            (EngineMode::Interp, "simple".into(), "tlb".into())
        );
        assert!(c.set("switch-to", "lockstep:inorder").is_err(), "missing field");
        assert!(c.set("switch-to", "warp:inorder:mesi").is_err(), "bad mode");
        assert!(c.set("switch-to", "parallel:atomic:mesi").is_err(), "Table 2 violation");
        assert!(c.set("switch-at", "soon").is_err());
    }

    #[test]
    fn engine_mode_codes_round_trip() {
        for mode in [
            EngineMode::Interp,
            EngineMode::Lockstep,
            EngineMode::Parallel,
            EngineMode::Sharded,
        ] {
            assert_eq!(EngineMode::from_code(mode.code()), Some(mode));
            assert_eq!(EngineMode::parse(mode.as_str()), Some(mode));
        }
        assert_eq!(EngineMode::from_code(0), None);
        assert_eq!(EngineMode::from_code(7), None);
    }

    #[test]
    fn sharded_flags_parse_and_validate() {
        let mut c = SimConfig::default();
        c.set("mode", "sharded").unwrap();
        c.set("harts", "4").unwrap();
        c.set("shards", "4").unwrap();
        c.set("quantum", "1024").unwrap();
        c.set("memory", "mesi").unwrap(); // MESI is legal under sharding
        c.validate().unwrap();
        assert_eq!((c.shards, c.quantum), (4, 1024));
        assert!(c.set("shards", "0").is_err(), "zero shards rejected");
        assert!(c.set("quantum", "0").is_err(), "zero quantum rejected");
        c.set("shards", "33").unwrap();
        assert!(c.validate().is_err(), "shard count capped");
        // The sharded engine is a valid hand-off target.
        c.set("shards", "2").unwrap();
        c.set("switch-to", "sharded:inorder:cache").unwrap();
        assert_eq!(
            c.switch_target().unwrap(),
            (EngineMode::Sharded, "inorder".into(), "cache".into())
        );
    }

    #[test]
    fn ckpt_and_sample_flags_validate() {
        let mut c = SimConfig::default();
        c.set("ckpt-every", "1000").unwrap();
        assert!(c.validate().is_err(), "--ckpt-every without --ckpt-out");
        c.set("ckpt-out", "/tmp/x.ckpt").unwrap();
        c.validate().unwrap();
        assert!(c.set("ckpt-every", "0").is_err());

        let mut c = SimConfig::default();
        c.set("sample", "8:50000:200000").unwrap();
        c.validate().unwrap();
        assert_eq!(c.sample.as_ref().unwrap().periods, 8);
        assert!(c.set("sample", "8:50000").is_err());
        c.set("switch-at", "100").unwrap();
        assert!(c.validate().is_err(), "--sample excludes --switch-at");
        c.switch_at = None;
        c.set("switch-to", "parallel:atomic:atomic").unwrap();
        assert!(c.validate().is_err(), "parallel target cannot be measured");
        c.set("switch-to", "lockstep:simple:cache").unwrap();
        c.validate().unwrap();
        c.set("ckpt-out", "/tmp/x.ckpt").unwrap();
        assert!(c.validate().is_err(), "--sample excludes checkpointing");
    }

    #[test]
    fn adaptive_and_repartition_flags_validate() {
        // Happy path: sharded, threaded quantum, bounds in order.
        let mut c = SimConfig::default();
        c.set("mode", "sharded").unwrap();
        c.set("harts", "4").unwrap();
        c.set("shards", "2").unwrap();
        c.set("adaptive-quantum", "true").unwrap();
        c.set("quantum-min", "64").unwrap();
        c.set("quantum-max", "8192").unwrap();
        c.set("repartition-every", "100000").unwrap();
        c.validate().unwrap();
        assert_eq!(c.quantum_bounds(), (64, 8192));

        // Defaults apply when the bounds are not given.
        let mut c = SimConfig::default();
        c.set("mode", "sharded").unwrap();
        c.set("adaptive-quantum", "on").unwrap();
        c.validate().unwrap();
        assert_eq!(
            c.quantum_bounds(),
            (SimConfig::DEFAULT_QUANTUM_MIN, SimConfig::DEFAULT_QUANTUM_MAX)
        );

        // Self-tuning flags under a non-sharded mode are contradictory,
        // and the diagnostic names the engine-mode registry.
        let mut c = SimConfig::default();
        c.set("adaptive-quantum", "true").unwrap();
        let err = c.validate().unwrap_err();
        assert!(err.0.contains("interp|lockstep|parallel|sharded"), "registry list: {}", err.0);

        // Bounds without the controller are silently-dead flags — reject.
        let mut c = SimConfig::default();
        c.set("mode", "sharded").unwrap();
        c.set("quantum-min", "64").unwrap();
        assert!(c.validate().is_err(), "--quantum-min needs --adaptive-quantum");

        // Inverted bounds.
        let mut c = SimConfig::default();
        c.set("mode", "sharded").unwrap();
        c.set("adaptive-quantum", "true").unwrap();
        c.set("quantum-min", "4096").unwrap();
        c.set("quantum-max", "128").unwrap();
        assert!(c.validate().is_err(), "inverted bounds rejected");

        // The serialized schedule (quantum 1) has no barrier to tune.
        let mut c = SimConfig::default();
        c.set("mode", "sharded").unwrap();
        c.set("quantum", "1").unwrap();
        c.set("adaptive-quantum", "true").unwrap();
        assert!(c.validate().is_err(), "adaptive under quantum 1 rejected");

        // Re-partitioning a single shard cannot re-balance anything.
        let mut c = SimConfig::default();
        c.set("mode", "sharded").unwrap();
        c.set("repartition-every", "100000").unwrap();
        assert!(c.validate().is_err(), "--repartition-every with --shards 1 rejected");

        // Zero values are rejected at parse time, like --quantum 0.
        let mut c = SimConfig::default();
        assert!(c.set("quantum-min", "0").is_err());
        assert!(c.set("quantum-max", "0").is_err());
        assert!(c.set("repartition-every", "0").is_err());
        assert!(c.set("adaptive-quantum", "maybe").is_err());

        // The mode registry itself drives the --mode diagnostic.
        let err = c.set("mode", "warp").unwrap_err();
        assert!(err.0.contains("interp|lockstep|parallel|sharded"), "registry list: {}", err.0);
    }

    #[test]
    fn sampled_sharded_validation() {
        // Sampling under --mode sharded must measure under the sharded
        // engine: a non-sharded switch target would silently measure
        // something else entirely.
        let mut c = SimConfig::default();
        c.set("mode", "sharded").unwrap();
        c.set("harts", "4").unwrap();
        c.set("shards", "2").unwrap();
        c.set("sample", "4:1000:2000").unwrap();
        let err = c.validate().unwrap_err();
        assert!(err.0.contains("sharded:<pipeline>:<memory>"), "got: {}", err.0);
        c.set("switch-to", "sharded:inorder:cache").unwrap();
        c.validate().unwrap();
        // The adaptive controller composes with sampling.
        c.set("adaptive-quantum", "true").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn backend_flags_parse_and_validate() {
        let mut c = SimConfig::default();
        assert_eq!(c.backend, crate::dbt::Backend::Microop);
        c.set("backend", "microop").unwrap();
        c.validate().unwrap();
        assert!(c.set("backend", "jit").is_err());
        c.set("dump-native", "0x80000000").unwrap();
        assert_eq!(c.dump_native, Some(0x8000_0000));
        c.set("dump-native", "4096").unwrap();
        assert_eq!(c.dump_native, Some(4096));
        assert!(c.set("dump-native", "zzz").is_err());
        c.set("backend", "native").unwrap();
        // Native must validate exactly when the host supports it.
        assert_eq!(c.validate().is_ok(), crate::dbt::native_available());
    }

    #[test]
    fn obs_flags_parse_and_gate() {
        let mut c = SimConfig::default();
        assert!(!c.obs_enabled(), "observability defaults off");
        c.set("stats-every", "100000").unwrap();
        assert_eq!(c.stats_every, 100_000);
        assert!(c.obs_enabled());
        assert!(c.set("stats-every", "soon").is_err());

        let mut c = SimConfig::default();
        c.set("trace-out", "/tmp/trace.json").unwrap();
        assert_eq!(c.trace_out.as_deref(), Some("/tmp/trace.json"));
        assert!(c.trace_events, "--trace-out implies event capture");
        assert!(c.obs_enabled());
        c.set("obs-capacity", "1024").unwrap();
        assert_eq!(c.obs_capacity, 1024);
        assert!(c.set("obs-capacity", "0").is_err());
        c.validate().unwrap();

        let mut c = SimConfig::default();
        c.profile = true;
        assert!(c.obs_enabled());

        let mut c = SimConfig::default();
        c.set("sample", "4:1000:2000").unwrap();
        c.set("trace-out", "/tmp/trace.json").unwrap();
        assert!(c.validate().is_err(), "--sample excludes observability");
    }

    #[test]
    fn parallel_requires_atomic() {
        let mut c = SimConfig::default();
        c.set("mode", "parallel").unwrap();
        c.set("memory", "mesi").unwrap();
        assert!(c.validate().is_err());
        c.set("memory", "atomic").unwrap();
        c.validate().unwrap();
    }
}
