//! Fleet mode (DESIGN.md §13): fan one checkpoint out to N concurrent
//! guest instances over a bounded host worker pool.
//!
//! Restoring a checkpoint per instance the naive way costs a full DRAM
//! copy and a cold retranslation of all guest code — per instance. The
//! fleet driver amortises both across arbitrarily many instances:
//!
//!  - **COW DRAM** — the checkpoint's sparse page set is decoded once
//!    into an immutable [`SharedPageSet`]; every instance maps it
//!    read-only via [`Checkpoint::snapshot_cow`] and clones a page only
//!    on its first write ([`crate::mem::PhysMem`]'s copy-on-write mode).
//!  - **Shared code seed** — a warm-up instance runs first and its
//!    translated blocks are harvested into an `Arc`-shared
//!    [`CodeSeed`]; instances whose translation inputs match the seed's
//!    stamps materialise blocks from it instead of retranslating.
//!  - **Parameter sweeps** — per-instance `key=value` overrides from a
//!    CLI grid ([`sweep_grid`]) or a spec file ([`parse_spec`]); an
//!    invalid combination fails that instance's cell, never the fleet.
//!
//! Per-instance results aggregate into a [`FleetReport`]
//! (`BENCH_fleet.json`, schema `r2vm-fleet-v1`).

use super::{resume_engine, SimConfig};
use crate::bench::fleet::{FleetReport, InstanceResult, InstanceStats};
use crate::ckpt::Checkpoint;
use crate::dbt::{Backend, CodeSeed};
use crate::engine::ExecutionEngine;
use crate::mem::SharedPageSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Keys the fleet driver owns. A sweep that set one would break the
/// fan-out invariants — shared guest topology, no per-instance file
/// outputs, and the flat-DRAM-only native backend — so they are
/// rejected per instance.
const FLEET_LOCKED_KEYS: &[&str] = &[
    "restore",
    "ckpt-out",
    "ckpt-every",
    "sample",
    "trace-out",
    "stats-every",
    "backend",
    "dump-native",
    "harts",
    "dram-mb",
];

/// Options of one fleet invocation.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Guest instances to run.
    pub instances: usize,
    /// Host worker threads (0 = one per available core; always clamped
    /// to the instance count).
    pub workers: usize,
    /// Instruction budget of the warm-up instance whose translations
    /// seed the shared code cache (0 skips the warm-up).
    pub warmup: u64,
    /// Share the warm-up instance's translated code with the fleet.
    pub share_code: bool,
    /// Per-instance parameter combinations; instance `i` runs combo
    /// `i % combos.len()`. Never empty — an empty sweep is one empty
    /// combo.
    pub combos: Vec<Vec<(String, String)>>,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            instances: 1,
            workers: 0,
            warmup: 200_000,
            share_code: true,
            combos: vec![Vec::new()],
        }
    }
}

/// Expand repeated `--sweep key=v1,v2` options into their cartesian
/// product, first key varying slowest. No sweeps yield the single empty
/// combo.
pub fn sweep_grid(sweeps: &[(String, Vec<String>)]) -> Vec<Vec<(String, String)>> {
    let mut combos: Vec<Vec<(String, String)>> = vec![Vec::new()];
    for (key, values) in sweeps {
        let mut next = Vec::with_capacity(combos.len() * values.len());
        for combo in &combos {
            for v in values {
                let mut c = combo.clone();
                c.push((key.clone(), v.clone()));
                next.push(c);
            }
        }
        combos = next;
    }
    combos
}

/// Parse a sweep spec file: one instance combo per non-empty,
/// non-comment line, each a whitespace-separated list of `key=value`
/// overrides (an intentionally blank combo is a lone `=`-free line —
/// not supported; use the CLI with no `--sweep` for unswept fleets).
pub fn parse_spec(text: &str) -> Result<Vec<Vec<(String, String)>>, String> {
    let mut combos = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut combo = Vec::new();
        for token in line.split_whitespace() {
            let Some((k, v)) = token.split_once('=') else {
                return Err(format!("spec line {}: '{}' is not key=value", lineno + 1, token));
            };
            if k.is_empty() {
                return Err(format!("spec line {}: empty key in '{}'", lineno + 1, token));
            }
            combo.push((k.to_string(), v.to_string()));
        }
        combos.push(combo);
    }
    if combos.is_empty() {
        return Err("spec file has no instance lines".into());
    }
    Ok(combos)
}

/// Fan `ckpt` out to `opts.instances` guest instances over a bounded
/// worker pool. `cfg` is the base configuration every instance starts
/// from (models, budgets — `--max-insts` counts total retirement
/// exactly as in [`super::run_restored`]); the checkpoint stays
/// authoritative for guest topology.
pub fn run_fleet(cfg: &SimConfig, ckpt: &Checkpoint, opts: &FleetOptions) -> FleetReport {
    let t0 = Instant::now();
    let mut base = cfg.clone();
    base.harts = ckpt.num_harts();
    base.dram_bytes = ckpt.dram_size as usize;
    // Fleet-managed fields: instances share the host, so none may write
    // files or sample; COW DRAM pins the portable micro-op backend (the
    // native backend's direct-access bias requires flat DRAM).
    base.restore = None;
    base.ckpt_out = None;
    base.ckpt_every = None;
    base.sample = None;
    base.trace_out = None;
    base.trace_events = false;
    base.stats_every = 0;
    base.profile = false;
    base.dump_native = None;
    base.backend = Backend::Microop;
    base.validate().expect("fleet base configuration must be valid");

    // Decode the page set once; every instance maps it read-only.
    let shared = ckpt.shared_pages();
    // Post-checkpoint deltas are measured against the checkpoint's own
    // clocks.
    let insts0 = ckpt.total_instret();
    let cycles0: u64 = ckpt.harts.iter().map(|h| h.cycle).sum();

    // Warm-up: translate the hot code once, share it with everyone.
    // Harvest *before* drop — suspending would flush the caches.
    let mut seed: Option<Arc<CodeSeed>> = None;
    let mut warmup_translations = 0u64;
    if opts.share_code && opts.warmup > 0 {
        let mut engine = resume_engine(&base, ckpt.snapshot_cow(&shared));
        engine.run(opts.warmup);
        warmup_translations = engine.stats().blocks_translated;
        seed = engine.take_code_seed();
    }
    let seed_blocks = seed.as_ref().map_or(0, |s| s.len() as u64);

    let n = opts.instances.max(1);
    let workers = if opts.workers == 0 {
        std::thread::available_parallelism().map_or(1, |p| p.get())
    } else {
        opts.workers
    }
    .min(n);

    // Bounded pool over an atomic work index: workers claim the next
    // unclaimed instance until the fleet is drained.
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<InstanceResult>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = run_instance(i, &base, ckpt, &shared, seed.as_ref(), opts, insts0, cycles0);
                results.lock().unwrap()[i] = Some(r);
            });
        }
    });
    let results = results
        .into_inner()
        .expect("no worker panicked holding the results lock")
        .into_iter()
        .map(|r| r.expect("every index was claimed by a worker"))
        .collect();
    FleetReport {
        instances: n,
        workers,
        wall_secs: t0.elapsed().as_secs_f64(),
        shared_pages: shared.content_pages(),
        warmup_translations,
        seed_blocks,
        results,
    }
}

/// Configure, COW-restore, seed and drive one instance. Every failure
/// is a recorded cell error, never a panic.
#[allow(clippy::too_many_arguments)]
fn run_instance(
    index: usize,
    base: &SimConfig,
    ckpt: &Checkpoint,
    shared: &Arc<SharedPageSet>,
    seed: Option<&Arc<CodeSeed>>,
    opts: &FleetOptions,
    insts0: u64,
    cycles0: u64,
) -> InstanceResult {
    let params = opts.combos[index % opts.combos.len()].clone();
    let mut cfg = base.clone();
    for (k, v) in &params {
        if FLEET_LOCKED_KEYS.contains(&k.as_str()) {
            return InstanceResult {
                index,
                params: params.clone(),
                outcome: Err(format!("--{} is fleet-managed and cannot be swept", k)),
            };
        }
        if let Err(e) = cfg.set(k, v) {
            return InstanceResult { index, params: params.clone(), outcome: Err(e.to_string()) };
        }
    }
    if let Err(e) = cfg.validate() {
        return InstanceResult { index, params, outcome: Err(e.to_string()) };
    }
    // Restore = build a snapshot over the shared page set (no DRAM
    // copy), resume an engine over it, install the shared code seed.
    let tr = Instant::now();
    let snapshot = ckpt.snapshot_cow(shared);
    let phys = Arc::clone(&snapshot.phys);
    let stage = cfg.clone();
    let mut engine = resume_engine(&stage, snapshot);
    if let Some(seed) = seed {
        engine.set_code_seed(seed);
    }
    let restore_secs = tr.elapsed().as_secs_f64();
    let report = super::drive(&cfg, stage, engine);
    let stats = report.engine_stats.unwrap_or_default();
    let insts = report.total_insts.saturating_sub(insts0);
    let cycles = report.per_hart.iter().map(|&(c, _)| c).sum::<u64>().saturating_sub(cycles0);
    InstanceResult {
        index,
        params,
        outcome: Ok(InstanceStats {
            exit: format!("{:?}", report.exit),
            insts,
            cycles,
            wall_secs: report.wall.as_secs_f64(),
            restore_secs,
            pages_mapped: phys.cow_pages_mapped(),
            pages_cloned: phys.cow_pages_cloned(),
            seed_hits: stats.seed_hits,
            translations: stats.blocks_translated,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::*;
    use crate::coordinator::{build_engine, run_restored};
    use crate::engine::ExitReason;
    use crate::mem::DRAM_BASE;

    /// Computes sum(1..=n), storing the running sum into its own
    /// (checkpointed) page each iteration so restored instances dirty a
    /// shared COW page.
    fn store_countdown(n: i64) -> Image {
        let mut a = Assembler::new(DRAM_BASE);
        let cell = a.new_label();
        a.li(A0, n);
        a.li(A1, 0);
        a.la(T0, cell);
        let top = a.here();
        a.add(A1, A1, A0);
        a.sd(A1, T0, 0);
        a.addi(A0, A0, -1);
        a.bnez(A0, top);
        a.mv(A0, A1);
        a.li(A7, 93);
        a.ecall();
        a.align(8);
        a.bind(cell);
        a.d64(0);
        a.finish()
    }

    fn mid_run_ckpt() -> Checkpoint {
        let cfg = SimConfig::default();
        let img = store_countdown(2_000);
        let mut engine = build_engine(&cfg, &img);
        assert_eq!(engine.run(1_000), ExitReason::StepLimit);
        let snap = engine.suspend();
        Checkpoint::from_snapshot(&snap)
    }

    #[test]
    fn sweep_grid_is_cartesian() {
        assert_eq!(sweep_grid(&[]), vec![Vec::new()], "no sweep = one empty combo");
        let grid = sweep_grid(&[
            ("pipeline".into(), vec!["simple".into(), "inorder".into()]),
            ("memory".into(), vec!["atomic".into(), "cache".into(), "tlb".into()]),
        ]);
        assert_eq!(grid.len(), 6);
        assert_eq!(
            grid[0],
            vec![("pipeline".into(), "simple".into()), ("memory".into(), "atomic".into())]
        );
        assert_eq!(
            grid[5],
            vec![("pipeline".into(), "inorder".into()), ("memory".into(), "tlb".into())]
        );
    }

    #[test]
    fn spec_lines_parse() {
        let combos = parse_spec(
            "# comment\n\npipeline=simple memory=cache\n  pipeline=inorder\t max-insts=5000 \n",
        )
        .unwrap();
        assert_eq!(combos.len(), 2);
        assert_eq!(
            combos[0],
            vec![("pipeline".into(), "simple".into()), ("memory".into(), "cache".into())]
        );
        assert_eq!(combos[1][1], ("max-insts".into(), "5000".into()));
        assert!(parse_spec("pipeline simple\n").is_err(), "not key=value");
        assert!(parse_spec("=x\n").is_err(), "empty key");
        assert!(parse_spec("# only comments\n").is_err(), "no instances");
    }

    #[test]
    fn fleet_shares_pages_and_code_across_instances() {
        let ckpt = mid_run_ckpt();
        let opts = FleetOptions { instances: 4, workers: 2, warmup: 500_000, ..Default::default() };
        let report = run_fleet(&SimConfig::default(), &ckpt, &opts);
        assert_eq!(report.failed(), 0, "{}", report.table());
        let ok = report.ok();
        assert_eq!(ok.len(), 4);
        for s in &ok {
            assert!(s.exit.contains("Exited"), "{}", s.exit);
            assert_eq!(s.insts, ok[0].insts, "identical configs retire identically");
            assert!(s.pages_mapped >= 1);
            assert!(s.pages_cloned >= 1, "the store dirties a shared page");
            assert!(s.pages_cloned <= s.pages_mapped, "cloning is bounded by the mapping");
        }
        assert!(report.warmup_translations > 0);
        assert!(report.seed_blocks > 0);
        assert!(report.seed_hits_total() > 0, "instances reuse the warm-up's translations");
        // Code amortisation: a solo restore translates everything cold;
        // the whole seeded fleet must translate no more than that.
        let solo = run_restored(&SimConfig::default(), mid_run_ckpt());
        let solo_tx = solo.engine_stats.unwrap_or_default().blocks_translated;
        assert!(solo_tx > 0);
        assert!(
            report.translations_total() <= solo_tx,
            "fleet translated {} vs solo {}",
            report.translations_total(),
            solo_tx
        );
    }

    #[test]
    fn sweep_varies_instances_and_locked_keys_fail_only_their_cell() {
        let ckpt = mid_run_ckpt();
        let opts = FleetOptions {
            instances: 3,
            workers: 1,
            warmup: 0,
            combos: vec![
                vec![("pipeline".into(), "inorder".into())],
                vec![("ckpt-out".into(), "/tmp/forbidden".into())],
                vec![("pipeline".into(), "nonsense".into())],
            ],
            ..Default::default()
        };
        let report = run_fleet(&SimConfig::default(), &ckpt, &opts);
        assert_eq!(report.failed(), 2, "{}", report.table());
        assert!(report.results[0].outcome.is_ok());
        let locked = report.results[1].outcome.as_ref().unwrap_err();
        assert!(locked.contains("fleet-managed"), "{}", locked);
        let unknown = report.results[2].outcome.as_ref().unwrap_err();
        assert!(unknown.contains("pipeline"), "{}", unknown);
        // The surviving inorder instance tracked cycles.
        let s = report.results[0].outcome.as_ref().unwrap();
        assert!(s.cycles > 0);
        assert!(s.insts > 0);
    }
}
