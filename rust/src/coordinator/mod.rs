//! Simulation coordinator: builds engines from a [`SimConfig`], dispatches
//! between the three execution modes (Figure 5), aggregates statistics,
//! and exposes the model inventory (Tables 1 and 2).

pub mod config;
pub mod parallel;

pub use config::{EngineMode, SimConfig};

use crate::analytics::trace::TraceCapture;
use crate::asm::Image;
use crate::fiber::FiberEngine;
use crate::interp::{ExitReason, InterpEngine};
use crate::mem::cache_model::CacheModel;
use crate::mem::mesi::MesiModel;
use crate::mem::tlb_model::TlbModel;
use crate::mem::{AtomicModel, MemoryModel};
use crate::sys::loader::load_flat;
use crate::sys::System;
use std::time::Instant;

/// Construct a memory model by name.
pub fn memory_model_by_name(
    name: &str,
    cfg: &SimConfig,
) -> Option<Box<dyn MemoryModel>> {
    match name {
        "atomic" => Some(Box::new(AtomicModel)),
        "tlb" => Some(Box::new(TlbModel::new(cfg.harts, cfg.timing))),
        "cache" => Some(Box::new(CacheModel::with_geometry(cfg.harts, cfg.timing, cfg.l1_geom))),
        "mesi" => Some(Box::new(MesiModel::with_geometry(
            cfg.harts,
            cfg.timing,
            cfg.l1_geom,
            cfg.l2_geom,
        ))),
        _ => None,
    }
}

/// Pre-implemented pipeline models — Table 1 of the paper.
pub const PIPELINE_TABLE: &[(&str, &str)] = &[
    ("Atomic", "Cycle count not tracked"),
    ("Simple", "Each non-memory instruction takes one cycle"),
    ("InOrder", "Models a simple 5-stage in-order scalar pipeline"),
];

/// Pre-implemented memory models — Table 2 of the paper.
pub const MEMORY_TABLE: &[(&str, &str)] = &[
    ("Atomic", "Memory accesses not tracked"),
    ("TLB", "TLB hit rate collected; cache not simulated"),
    ("Cache", "Cache hit rate collected; TLB and cache coherency not modelled; parallel execution allowed"),
    ("MESI", "A directory-based MESI cache coherency protocol with a shared L2. Lockstep execution required."),
];

/// Render Tables 1 + 2 for the `models` CLI command.
pub fn models_report() -> String {
    let mut s = String::new();
    s.push_str("Table 1: pipeline models\n");
    for (name, desc) in PIPELINE_TABLE {
        s.push_str(&format!("  {:<8} {}\n", name, desc));
    }
    s.push_str("\nTable 2: memory models\n");
    for (name, desc) in MEMORY_TABLE {
        s.push_str(&format!("  {:<8} {}\n", name, desc));
    }
    s
}

/// Result of one simulation run.
pub struct RunReport {
    pub exit: ExitReason,
    pub wall: std::time::Duration,
    pub total_insts: u64,
    /// Per-hart (cycle, instret).
    pub per_hart: Vec<(u64, u64)>,
    pub console: String,
    /// Memory-model statistics snapshot.
    pub model_stats: Vec<(&'static str, u64)>,
    /// Engine statistics (lockstep mode only).
    pub engine_stats: Option<crate::fiber::EngineStats>,
}

impl RunReport {
    pub fn mips(&self) -> f64 {
        self.total_insts as f64 / self.wall.as_secs_f64() / 1e6
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "exit={:?} insts={} wall={:.3}s mips={:.1}\n",
            self.exit,
            self.total_insts,
            self.wall.as_secs_f64(),
            self.mips()
        );
        for (i, (cyc, ins)) in self.per_hart.iter().enumerate() {
            s.push_str(&format!("  hart{}: mcycle={} minstret={}\n", i, cyc, ins));
        }
        for (k, v) in &self.model_stats {
            s.push_str(&format!("  {}={}\n", k, v));
        }
        s
    }
}

/// Build the `System` described by `cfg`.
pub fn build_system(cfg: &SimConfig) -> System {
    let model = memory_model_by_name(&cfg.memory, cfg).expect("validated");
    let mut sys = System::with_model(cfg.harts, cfg.dram_bytes, model);
    sys.set_line_shift(cfg.line_shift);
    sys.force_cold = cfg.no_l0;
    sys.bus.uart.echo = cfg.console;
    if cfg.trace_capacity > 0 {
        sys.trace = Some(TraceCapture::new(cfg.trace_capacity));
    }
    sys.simctrl_state = simctrl_encoding(&cfg.pipeline, &cfg.memory, cfg.line_shift);
    sys
}

/// Pack the current configuration in the SIMCTRL CSR encoding.
pub fn simctrl_encoding(pipeline: &str, memory: &str, line_shift: u32) -> u64 {
    let p = match pipeline {
        "atomic" => 1,
        "simple" => 2,
        "inorder" | "in-order" => 3,
        _ => 0,
    };
    let m: u64 = match memory {
        "atomic" => 1,
        "tlb" => 2,
        "cache" => 3,
        "mesi" => 4,
        _ => 0,
    };
    p | (m << 4) | (((1u64 << line_shift) & 0xfff) << 8)
}

/// Run `image` to completion under `cfg`.
pub fn run_image(cfg: &SimConfig, image: &Image) -> RunReport {
    cfg.validate().expect("invalid configuration");
    match cfg.mode {
        EngineMode::Interp => {
            let sys = build_system(cfg);
            let mut eng = InterpEngine::new(sys);
            let entry = load_flat(&eng.sys, image);
            for h in &mut eng.harts {
                h.pc = entry;
            }
            let t0 = Instant::now();
            let exit = eng.run(cfg.max_insts);
            let wall = t0.elapsed();
            RunReport {
                exit,
                wall,
                total_insts: eng.total_instret(),
                per_hart: eng.harts.iter().map(|h| (h.cycle, h.instret)).collect(),
                console: eng.sys.bus.uart.output_str(),
                model_stats: eng.sys.model.stats(),
                engine_stats: None,
            }
        }
        EngineMode::Lockstep => {
            let sys = build_system(cfg);
            let mut eng = FiberEngine::new(sys, &cfg.pipeline);
            eng.timing = cfg.timing;
            eng.yield_per_instruction = cfg.naive_yield;
            eng.chaining = !cfg.no_chaining;
            let entry = load_flat(&eng.sys, image);
            eng.set_entry(entry);
            let t0 = Instant::now();
            let exit = eng.run(cfg.max_insts);
            let wall = t0.elapsed();
            RunReport {
                exit,
                wall,
                total_insts: eng.total_instret(),
                per_hart: eng.harts.iter().map(|h| (h.cycle, h.instret)).collect(),
                console: eng.sys.bus.uart.output_str(),
                model_stats: eng.sys.model.stats(),
                engine_stats: Some(eng.stats),
            }
        }
        EngineMode::Parallel => parallel::run_parallel(cfg, image),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::*;
    use crate::mem::DRAM_BASE;

    fn countdown(n: i64) -> Image {
        let mut a = Assembler::new(DRAM_BASE);
        a.li(A0, n);
        a.li(A1, 0);
        let top = a.here();
        a.add(A1, A1, A0);
        a.addi(A0, A0, -1);
        a.bnez(A0, top);
        a.mv(A0, A1);
        a.li(A7, 93);
        a.ecall();
        a.finish()
    }

    #[test]
    fn all_modes_agree_on_result() {
        let img = countdown(99);
        let want = ExitReason::Exited(99 * 100 / 2);
        for mode in ["interp", "lockstep", "parallel"] {
            let mut cfg = SimConfig::default();
            cfg.set("mode", mode).unwrap();
            cfg.set("memory", "atomic").unwrap();
            cfg.pipeline = "atomic".into();
            let report = run_image(&cfg, &img);
            assert_eq!(report.exit, want, "mode {}", mode);
        }
    }

    #[test]
    fn model_matrix_smoke() {
        let img = countdown(25);
        for memory in ["atomic", "tlb", "cache", "mesi"] {
            for pipeline in ["atomic", "simple", "inorder"] {
                let mut cfg = SimConfig::default();
                cfg.set("memory", memory).unwrap();
                cfg.pipeline = pipeline.into();
                let report = run_image(&cfg, &img);
                assert_eq!(
                    report.exit,
                    ExitReason::Exited(325),
                    "pipeline={} memory={}",
                    pipeline,
                    memory
                );
            }
        }
    }

    #[test]
    fn timing_models_order_sanely() {
        // For the same program: inorder+mesi >= simple+cache >= simple+atomic
        // in simulated cycles.
        let img = countdown(500);
        let cycles = |pipeline: &str, memory: &str| {
            let mut cfg = SimConfig::default();
            cfg.pipeline = pipeline.into();
            cfg.set("memory", memory).unwrap();
            let r = run_image(&cfg, &img);
            r.per_hart[0].0
        };
        let base = cycles("simple", "atomic");
        let cache = cycles("simple", "cache");
        let full = cycles("inorder", "mesi");
        assert!(cache >= base, "cache {} >= atomic {}", cache, base);
        assert!(full >= cache, "inorder+mesi {} >= simple+cache {}", full, cache);
    }

    #[test]
    fn models_report_lists_tables() {
        let r = models_report();
        assert!(r.contains("InOrder"));
        assert!(r.contains("MESI"));
        assert!(r.contains("Lockstep execution required"));
    }

    #[test]
    fn simctrl_encoding_roundtrip() {
        let v = simctrl_encoding("inorder", "mesi", 6);
        assert_eq!(v & 0b111, 3);
        assert_eq!((v >> 4) & 0b111, 4);
        assert_eq!((v >> 8) & 0xfff, 64);
    }
}
