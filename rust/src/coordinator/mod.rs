//! Simulation coordinator: builds engines from a [`SimConfig`], drives
//! them through the [`ExecutionEngine`] interface, performs run-time
//! engine hand-offs (guest SIMCTRL requests or the `--switch-at` budget),
//! aggregates statistics, and exposes the model inventory (Tables 1-2).
//!
//! A run is a sequence of *stages*. Each stage is one engine built over
//! the same guest DRAM; between stages the guest travels as a
//! [`SystemSnapshot`]. The canonical workflow (paper §3.5, Schnerr et
//! al.'s fast-forward-then-measure): boot under `parallel/atomic` at
//! maximum MIPS, then hand off to `lockstep/inorder+mesi` for the region
//! of interest.

pub mod config;
pub mod parallel;

pub use config::{EngineMode, SimConfig};
pub use parallel::ParallelEngine;

use crate::analytics::trace::TraceCapture;
use crate::asm::Image;
use crate::engine::{
    line_shift_by_code, memory_name_by_code, pipeline_name_by_code, EngineStats, ExecutionEngine,
    ExitReason,
};
use crate::fiber::FiberEngine;
use crate::interp::InterpEngine;
use crate::isa::csr::SIMCTRL_ENGINE_SHIFT;
use crate::mem::cache_model::CacheModel;
use crate::mem::mesi::MesiModel;
use crate::mem::tlb_model::TlbModel;
use crate::mem::{AtomicModel, MemoryModel, PhysMem, DRAM_BASE};
use crate::sys::loader::load_flat;
use crate::sys::{System, SystemSnapshot};
use std::sync::Arc;
use std::time::Instant;

/// Construct a memory model by name.
pub fn memory_model_by_name(
    name: &str,
    cfg: &SimConfig,
) -> Option<Box<dyn MemoryModel>> {
    match name {
        "atomic" => Some(Box::new(AtomicModel)),
        "tlb" => Some(Box::new(TlbModel::new(cfg.harts, cfg.timing))),
        "cache" => Some(Box::new(CacheModel::with_geometry(cfg.harts, cfg.timing, cfg.l1_geom))),
        "mesi" => Some(Box::new(MesiModel::with_geometry(
            cfg.harts,
            cfg.timing,
            cfg.l1_geom,
            cfg.l2_geom,
        ))),
        _ => None,
    }
}

/// Pre-implemented pipeline models — Table 1 of the paper.
pub const PIPELINE_TABLE: &[(&str, &str)] = &[
    ("Atomic", "Cycle count not tracked"),
    ("Simple", "Each non-memory instruction takes one cycle"),
    ("InOrder", "Models a simple 5-stage in-order scalar pipeline"),
];

/// Pre-implemented memory models — Table 2 of the paper.
pub const MEMORY_TABLE: &[(&str, &str)] = &[
    ("Atomic", "Memory accesses not tracked"),
    ("TLB", "TLB hit rate collected; cache not simulated"),
    ("Cache", "Cache hit rate collected; TLB and cache coherency not modelled; parallel execution allowed"),
    ("MESI", "A directory-based MESI cache coherency protocol with a shared L2. Lockstep execution required."),
];

/// Execution engines — run-time switchable (§3.5 extended).
pub const ENGINE_TABLE: &[(&str, &str)] = &[
    ("interp", "Naive per-cycle interpreter (gem5-like lockstep baseline)"),
    ("lockstep", "Single-threaded lockstep DBT; supports every timing model"),
    ("parallel", "One host thread per hart over shared DRAM; atomic memory model only"),
];

/// Render Tables 1 + 2 and the engine inventory for the `models` command.
pub fn models_report() -> String {
    let mut s = String::new();
    s.push_str("Table 1: pipeline models\n");
    for (name, desc) in PIPELINE_TABLE {
        s.push_str(&format!("  {:<8} {}\n", name, desc));
    }
    s.push_str("\nTable 2: memory models\n");
    for (name, desc) in MEMORY_TABLE {
        s.push_str(&format!("  {:<8} {}\n", name, desc));
    }
    s.push_str("\nExecution engines (run-time switchable):\n");
    for (name, desc) in ENGINE_TABLE {
        s.push_str(&format!("  {:<8} {}\n", name, desc));
    }
    s.push_str(
        "\nEngine hand-off: the guest writes SIMCTRL (0x7C0) bits [22:20]\n\
         (1=interp 2=lockstep 3=parallel, 0=keep), or pass --switch-at N to\n\
         hand off to the --switch-to target after N retired instructions.\n\
         Hart state, DRAM, IPIs and device state carry over; the new engine\n\
         starts with cold code caches and L0s.\n",
    );
    s
}

/// Result of one simulation run.
pub struct RunReport {
    pub exit: ExitReason,
    pub wall: std::time::Duration,
    pub total_insts: u64,
    /// Per-hart (cycle, instret).
    pub per_hart: Vec<(u64, u64)>,
    pub console: String,
    /// Memory-model statistics snapshot (final stage).
    pub model_stats: Vec<(&'static str, u64)>,
    /// Engine statistics accumulated across all stages.
    pub engine_stats: Option<EngineStats>,
    /// Engine/model configuration of each stage, in hand-off order.
    pub stages: Vec<String>,
}

impl RunReport {
    /// Host-side simulation rate. Guarded against zero/denormal wall
    /// clocks: trivial runs on fast hosts can complete between two timer
    /// ticks, and `inf`/`NaN` rates poison downstream statistics.
    pub fn mips(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 || self.total_insts == 0 {
            return 0.0;
        }
        self.total_insts as f64 / secs / 1e6
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "exit={:?} insts={} wall={:.3}s mips={:.1}\n",
            self.exit,
            self.total_insts,
            self.wall.as_secs_f64(),
            self.mips()
        );
        if self.stages.len() > 1 {
            s.push_str(&format!("  stages: {}\n", self.stages.join(" -> ")));
        }
        for (i, (cyc, ins)) in self.per_hart.iter().enumerate() {
            s.push_str(&format!("  hart{}: mcycle={} minstret={}\n", i, cyc, ins));
        }
        for (k, v) in &self.model_stats {
            s.push_str(&format!("  {}={}\n", k, v));
        }
        s
    }
}

/// Build a `System` for `cfg` over existing guest DRAM (hand-off path).
fn system_over(cfg: &SimConfig, phys: Arc<PhysMem>) -> System {
    let model = memory_model_by_name(&cfg.memory, cfg).expect("validated");
    let mut sys = System::with_shared_phys(cfg.harts, phys, model);
    sys.set_line_shift(cfg.line_shift);
    sys.force_cold = cfg.no_l0;
    sys.bus.uart.echo = cfg.console;
    sys.timing = cfg.timing;
    if cfg.trace_capacity > 0 {
        sys.trace = Some(TraceCapture::new(cfg.trace_capacity));
    }
    sys.simctrl_state =
        simctrl_encoding_full(cfg.mode, &cfg.pipeline, &cfg.memory, cfg.line_shift);
    sys
}

/// Build the `System` described by `cfg` with fresh guest DRAM.
pub fn build_system(cfg: &SimConfig) -> System {
    system_over(cfg, Arc::new(PhysMem::new(DRAM_BASE, cfg.dram_bytes)))
}

/// Pack the current model configuration in the SIMCTRL CSR encoding
/// (engine field left at 0 = keep).
pub fn simctrl_encoding(pipeline: &str, memory: &str, line_shift: u32) -> u64 {
    let p = match pipeline {
        "atomic" => 1,
        "simple" => 2,
        "inorder" | "in-order" => 3,
        _ => 0,
    };
    let m: u64 = match memory {
        "atomic" => 1,
        "tlb" => 2,
        "cache" => 3,
        "mesi" => 4,
        _ => 0,
    };
    p | (m << 4) | (((1u64 << line_shift) & 0xfff) << 8)
}

/// Full SIMCTRL encoding including the engine-request field — what a
/// guest writes to trigger an engine-level hand-off (§3.5 extended).
pub fn simctrl_encoding_full(
    mode: EngineMode,
    pipeline: &str,
    memory: &str,
    line_shift: u32,
) -> u64 {
    simctrl_encoding(pipeline, memory, line_shift) | (mode.code() << SIMCTRL_ENGINE_SHIFT)
}

/// Decode a SIMCTRL write into a stage configuration: nonzero fields
/// override, zero fields keep the current value. Combinations that
/// violate Table 2 (the parallel engine requires the atomic memory model)
/// are sanitised rather than rejected — a guest-triggered hand-off must
/// not abort the simulation.
pub fn apply_simctrl_to_config(cfg: &mut SimConfig, value: u64) {
    if let Some(mode) = EngineMode::from_code((value >> SIMCTRL_ENGINE_SHIFT) & 0b111) {
        cfg.mode = mode;
    }
    if let Some(pipeline) = pipeline_name_by_code(value & 0b111) {
        cfg.pipeline = pipeline.into();
    }
    if let Some(memory) = memory_name_by_code((value >> 4) & 0b111) {
        cfg.memory = memory.into();
    }
    if let Some(shift) = line_shift_by_code(value) {
        cfg.line_shift = shift;
    }
    if cfg.mode == EngineMode::Parallel && cfg.memory != "atomic" {
        cfg.memory = "atomic".into();
    }
}

/// Human-readable stage label for reports.
fn stage_label(cfg: &SimConfig) -> String {
    format!("{}/{}+{}", cfg.mode.as_str(), cfg.pipeline, cfg.memory)
}

/// Build an engine for `cfg` and boot it from a flat image.
pub fn build_engine(cfg: &SimConfig, image: &Image) -> Box<dyn ExecutionEngine> {
    match cfg.mode {
        EngineMode::Interp => {
            let sys = build_system(cfg);
            let mut eng = InterpEngine::new(sys);
            let entry = load_flat(&eng.sys, image);
            for h in &mut eng.harts {
                h.pc = entry;
            }
            Box::new(eng)
        }
        EngineMode::Lockstep => {
            let sys = build_system(cfg);
            let mut eng = FiberEngine::new(sys, &cfg.pipeline);
            eng.yield_per_instruction = cfg.naive_yield;
            eng.chaining = !cfg.no_chaining;
            let entry = load_flat(&eng.sys, image);
            eng.set_entry(entry);
            Box::new(eng)
        }
        EngineMode::Parallel => Box::new(ParallelEngine::from_image(cfg, image)),
    }
}

/// Build an engine for `cfg` warm-started from a snapshot (the second
/// half of an engine hand-off).
pub fn resume_engine(cfg: &SimConfig, snapshot: SystemSnapshot) -> Box<dyn ExecutionEngine> {
    match cfg.mode {
        EngineMode::Interp => {
            let sys = system_over(cfg, Arc::clone(&snapshot.phys));
            let mut eng = InterpEngine::new(sys);
            eng.resume(snapshot);
            Box::new(eng)
        }
        EngineMode::Lockstep => {
            let sys = system_over(cfg, Arc::clone(&snapshot.phys));
            let mut eng = FiberEngine::new(sys, &cfg.pipeline);
            eng.yield_per_instruction = cfg.naive_yield;
            eng.chaining = !cfg.no_chaining;
            eng.resume(snapshot);
            Box::new(eng)
        }
        EngineMode::Parallel => Box::new(ParallelEngine::from_snapshot(cfg, snapshot)),
    }
}

/// Run `image` to completion under `cfg`, performing engine hand-offs as
/// requested by the guest (SIMCTRL engine field) or by `--switch-at`.
pub fn run_image(cfg: &SimConfig, image: &Image) -> RunReport {
    cfg.validate().expect("invalid configuration");
    let t0 = Instant::now();
    let mut stage = cfg.clone();
    let mut engine = build_engine(&stage, image);
    let mut stages = vec![stage_label(&stage)];
    let mut acc_stats = EngineStats::default();
    let mut switch_at = stage.switch_at;

    let exit = loop {
        // Budgets are in the unit the engine's `run` consumes: total
        // retired instructions for serial engines, per-hart for the
        // parallel engine (`budget_progress` reports the same unit).
        let progress = engine.budget_progress();
        let remaining = cfg.max_insts.saturating_sub(progress);
        let (budget, switch_bounded) = match switch_at {
            Some(at) => {
                let to_switch = at.saturating_sub(progress);
                if to_switch < remaining {
                    (to_switch, true)
                } else {
                    (remaining, false)
                }
            }
            None => (remaining, false),
        };
        // Decide the next stage's configuration; anything other than a
        // hand-off ends the run.
        match engine.run(budget) {
            ExitReason::SwitchRequest(value) => {
                // Guest-triggered hand-off: decode the full target
                // configuration from the CSR write.
                apply_simctrl_to_config(&mut stage, value);
            }
            ExitReason::StepLimit if switch_bounded => {
                // --switch-at boundary: hand off to the --switch-to target.
                let (mode, pipeline, memory) = stage.switch_target().expect("validated");
                stage.mode = mode;
                stage.pipeline = pipeline;
                stage.memory = memory;
            }
            other => break other,
        }
        // The hand-off itself is identical for both triggers.
        switch_at = None;
        acc_stats.merge(&engine.stats());
        let snapshot = engine.suspend();
        engine = resume_engine(&stage, snapshot);
        stages.push(stage_label(&stage));
    };
    let wall = t0.elapsed();
    acc_stats.merge(&engine.stats());
    RunReport {
        exit,
        wall,
        total_insts: engine.total_instret(),
        per_hart: engine.per_hart(),
        console: engine.console(),
        model_stats: engine.model_stats(),
        engine_stats: Some(acc_stats),
        stages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::*;
    use crate::mem::DRAM_BASE;

    fn countdown(n: i64) -> Image {
        let mut a = Assembler::new(DRAM_BASE);
        a.li(A0, n);
        a.li(A1, 0);
        let top = a.here();
        a.add(A1, A1, A0);
        a.addi(A0, A0, -1);
        a.bnez(A0, top);
        a.mv(A0, A1);
        a.li(A7, 93);
        a.ecall();
        a.finish()
    }

    #[test]
    fn all_modes_agree_on_result() {
        let img = countdown(99);
        let want = ExitReason::Exited(99 * 100 / 2);
        for mode in ["interp", "lockstep", "parallel"] {
            let mut cfg = SimConfig::default();
            cfg.set("mode", mode).unwrap();
            cfg.set("memory", "atomic").unwrap();
            cfg.pipeline = "atomic".into();
            let report = run_image(&cfg, &img);
            assert_eq!(report.exit, want, "mode {}", mode);
        }
    }

    #[test]
    fn model_matrix_smoke() {
        let img = countdown(25);
        for memory in ["atomic", "tlb", "cache", "mesi"] {
            for pipeline in ["atomic", "simple", "inorder"] {
                let mut cfg = SimConfig::default();
                cfg.set("memory", memory).unwrap();
                cfg.pipeline = pipeline.into();
                let report = run_image(&cfg, &img);
                assert_eq!(
                    report.exit,
                    ExitReason::Exited(325),
                    "pipeline={} memory={}",
                    pipeline,
                    memory
                );
            }
        }
    }

    #[test]
    fn timing_models_order_sanely() {
        // For the same program: inorder+mesi >= simple+cache >= simple+atomic
        // in simulated cycles.
        let img = countdown(500);
        let cycles = |pipeline: &str, memory: &str| {
            let mut cfg = SimConfig::default();
            cfg.pipeline = pipeline.into();
            cfg.set("memory", memory).unwrap();
            let r = run_image(&cfg, &img);
            r.per_hart[0].0
        };
        let base = cycles("simple", "atomic");
        let cache = cycles("simple", "cache");
        let full = cycles("inorder", "mesi");
        assert!(cache >= base, "cache {} >= atomic {}", cache, base);
        assert!(full >= cache, "inorder+mesi {} >= simple+cache {}", full, cache);
    }

    #[test]
    fn models_report_lists_tables() {
        let r = models_report();
        assert!(r.contains("InOrder"));
        assert!(r.contains("MESI"));
        assert!(r.contains("Lockstep execution required"));
        assert!(r.contains("lockstep"), "engine inventory must be listed");
        assert!(r.contains("--switch-at"));
    }

    #[test]
    fn simctrl_encoding_roundtrip() {
        let v = simctrl_encoding("inorder", "mesi", 6);
        assert_eq!(v & 0b111, 3);
        assert_eq!((v >> 4) & 0b111, 4);
        assert_eq!((v >> 8) & 0xfff, 64);
        assert_eq!((v >> SIMCTRL_ENGINE_SHIFT) & 0b111, 0, "plain encoding keeps the engine");
        let full = simctrl_encoding_full(EngineMode::Parallel, "atomic", "atomic", 6);
        assert_eq!((full >> SIMCTRL_ENGINE_SHIFT) & 0b111, 3);
    }

    #[test]
    fn mips_guards_zero_wall_clock() {
        let report = RunReport {
            exit: ExitReason::Exited(0),
            wall: std::time::Duration::ZERO,
            total_insts: 1_000_000,
            per_hart: vec![(0, 1_000_000)],
            console: String::new(),
            model_stats: Vec::new(),
            engine_stats: None,
            stages: vec!["lockstep/simple+atomic".into()],
        };
        assert_eq!(report.mips(), 0.0, "zero wall clock must not produce inf");
        assert!(report.summary().contains("mips=0.0"));
        let empty = RunReport { total_insts: 0, wall: std::time::Duration::from_secs(1), ..report };
        assert_eq!(empty.mips(), 0.0);
    }

    #[test]
    fn switch_at_hands_off_to_switch_to_target() {
        let img = countdown(2_000);
        let mut cfg = SimConfig::default();
        cfg.set("mode", "parallel").unwrap();
        cfg.pipeline = "atomic".into();
        cfg.set("switch-at", "1000").unwrap();
        let report = run_image(&cfg, &img);
        assert_eq!(report.exit, ExitReason::Exited(2_000 * 2_001 / 2));
        assert_eq!(report.stages.len(), 2, "exactly one hand-off: {:?}", report.stages);
        assert_eq!(report.stages[0], "parallel/atomic+atomic");
        assert_eq!(report.stages[1], "lockstep/inorder+mesi");
        // The measured stage runs under MESI: model stats must be present.
        assert!(!report.model_stats.is_empty());
    }
}
