//! Simulation coordinator: builds engines from a [`SimConfig`], drives
//! them through the [`ExecutionEngine`] interface, performs run-time
//! engine hand-offs (guest SIMCTRL requests or the `--switch-at` budget),
//! aggregates statistics, and exposes the model inventory (Tables 1-2).
//!
//! A run is a sequence of *stages*. Each stage is one engine built over
//! the same guest DRAM; between stages the guest travels as a
//! [`SystemSnapshot`]. The canonical workflow (paper §3.5, Schnerr et
//! al.'s fast-forward-then-measure): boot under `parallel/atomic` at
//! maximum MIPS, then hand off to `lockstep/inorder+mesi` for the region
//! of interest.

pub mod config;
pub mod fleet;
pub mod parallel;

pub use config::{EngineMode, SimConfig};
pub use fleet::{parse_spec, run_fleet, sweep_grid, FleetOptions};
pub use parallel::ParallelEngine;
pub use crate::sampling::run_sampled;

use crate::analytics::trace::TraceCapture;
use crate::asm::Image;
use crate::engine::{
    line_shift_by_code, memory_name_by_code, pipeline_name_by_code, EngineStats, ExecutionEngine,
    ExitReason,
};
use crate::fiber::{FiberEngine, ShardedEngine};
use crate::interp::InterpEngine;
use crate::isa::csr::SIMCTRL_ENGINE_SHIFT;
use crate::mem::cache_model::CacheModel;
use crate::mem::mesi::MesiModel;
use crate::mem::tlb_model::TlbModel;
use crate::mem::{AtomicModel, MemoryModel, PhysMem, DRAM_BASE};
use crate::obs::{Event, EventKind, Harvest, Obs, TRACK_COORDINATOR};
use crate::sys::loader::load_flat;
use crate::sys::{System, SystemSnapshot};
use std::sync::Arc;
use std::time::Instant;

/// Construct a memory model by name.
pub fn memory_model_by_name(
    name: &str,
    cfg: &SimConfig,
) -> Option<Box<dyn MemoryModel>> {
    match name {
        "atomic" => Some(Box::new(AtomicModel)),
        "tlb" => Some(Box::new(TlbModel::new(cfg.harts, cfg.timing))),
        "cache" => Some(Box::new(CacheModel::with_geometry(cfg.harts, cfg.timing, cfg.l1_geom))),
        "mesi" => Some(Box::new(MesiModel::with_geometry(
            cfg.harts,
            cfg.timing,
            cfg.l1_geom,
            cfg.l2_geom,
        ))),
        _ => None,
    }
}


/// Pre-implemented memory models — Table 2 of the paper.
pub const MEMORY_TABLE: &[(&str, &str)] = &[
    ("Atomic", "Memory accesses not tracked"),
    ("TLB", "TLB hit rate collected; cache not simulated"),
    ("Cache", "Cache hit rate collected; TLB and cache coherency not modelled; parallel execution allowed"),
    ("MESI", "A directory-based MESI cache coherency protocol with a shared L2. Lockstep execution required."),
];

/// Execution engines — run-time switchable (§3.5 extended).
pub const ENGINE_TABLE: &[(&str, &str)] = &[
    ("interp", "Naive per-cycle interpreter (gem5-like lockstep baseline)"),
    ("lockstep", "Single-threaded lockstep DBT; supports every timing model"),
    ("parallel", "One host thread per hart over shared DRAM; atomic memory model only"),
    (
        "sharded",
        "Cycle-level DBT over --shards host threads with deterministic --quantum barriers; \
         quantum 1 reproduces lockstep bit-exactly",
    ),
];

/// Render Tables 1 + 2 and the engine inventory for the `models` command.
pub fn models_report() -> String {
    let mut s = String::new();
    s.push_str("Table 1: pipeline models\n");
    // Derived from the model registry so a new pipeline model shows up
    // here (and in CLI error messages) without touching this file.
    for m in crate::pipeline::MODELS {
        s.push_str(&format!("  {:<8} {}\n", m.display, m.summary));
    }
    s.push_str("\nTable 2: memory models\n");
    for (name, desc) in MEMORY_TABLE {
        s.push_str(&format!("  {:<8} {}\n", name, desc));
    }
    s.push_str("\nExecution engines (run-time switchable):\n");
    for (name, desc) in ENGINE_TABLE {
        s.push_str(&format!("  {:<8} {}\n", name, desc));
    }
    s.push_str(
        "\nEngine hand-off: the guest writes SIMCTRL (0x7C0) bits [22:20]\n\
         (1=interp 2=lockstep 3=parallel 4=sharded, 0=keep), or pass\n\
         --switch-at N to hand off to the --switch-to target after N retired\n\
         instructions. Hart state, DRAM, IPIs and device state carry over;\n\
         the new engine starts with cold code caches and L0s.\n\
         The sharded engine takes --shards S and --quantum Q: results are a\n\
         pure function of (image, S, Q); Q=1 is bit-identical to lockstep.\n",
    );
    s
}

/// One stage's attributed share of a run. Counters are captured per stage
/// instead of accumulating silently across hand-offs, so the numbers in a
/// report are always attributable to the stage that produced them (the
/// boot phase's cache misses no longer pollute the ROI's hit rate).
#[derive(Debug, Clone)]
pub struct StageReport {
    pub label: String,
    /// Instructions retired during this stage (summed over harts).
    pub insts: u64,
    /// Cycles elapsed during this stage (summed over harts).
    pub cycles: u64,
    /// Memory-model statistics attributable to this stage, accumulated
    /// across any checkpoint re-spawns within it.
    pub model_stats: Vec<(&'static str, u64)>,
    /// Engine statistics attributable to this stage.
    pub engine_stats: EngineStats,
}

/// Sum `add` into `acc` by key; keys keep first-seen order so repeated
/// merges of the same model's stats stay in the model's own order.
pub fn merge_model_stats(acc: &mut Vec<(&'static str, u64)>, add: &[(&'static str, u64)]) {
    for &(k, v) in add {
        if let Some(entry) = acc.iter_mut().find(|(key, _)| *key == k) {
            entry.1 += v;
        } else {
            acc.push((k, v));
        }
    }
}

/// Summed (cycles, instret) across harts (shared with the sampling
/// driver's window arithmetic).
pub(crate) fn hart_totals(engine: &dyn ExecutionEngine) -> (u64, u64) {
    let mut cycles = 0;
    let mut insts = 0;
    for (c, i) in engine.per_hart() {
        cycles += c;
        insts += i;
    }
    (cycles, insts)
}

/// Result of one simulation run.
pub struct RunReport {
    pub exit: ExitReason,
    pub wall: std::time::Duration,
    pub total_insts: u64,
    /// Per-hart (cycle, instret).
    pub per_hart: Vec<(u64, u64)>,
    pub console: String,
    /// Memory-model statistics of the final stage (accumulated across its
    /// checkpoint re-spawns).
    pub model_stats: Vec<(&'static str, u64)>,
    /// Engine statistics accumulated across all stages.
    pub engine_stats: Option<EngineStats>,
    /// Engine/model configuration of each stage, in hand-off order.
    pub stages: Vec<String>,
    /// Per-stage attributed counters, parallel to `stages` for staged
    /// runs (empty for sampled runs, which report through `sampling`).
    pub stage_reports: Vec<StageReport>,
    /// Sampled-run aggregate (present only for `--sample` runs).
    pub sampling: Option<crate::sampling::SamplingSummary>,
    /// Observability harvest (events, per-PC profile, cache churn),
    /// merged across all stages. `None` when observability is off.
    pub obs: Option<Harvest>,
    /// Records dropped by the analytics `--trace` ring (`TraceCapture`),
    /// summed across stages — surfaced so truncation is never silent.
    pub trace_dropped: u64,
}

impl RunReport {
    /// Host-side simulation rate. Guarded against zero/denormal wall
    /// clocks: trivial runs on fast hosts can complete between two timer
    /// ticks, and `inf`/`NaN` rates poison downstream statistics.
    pub fn mips(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 || self.total_insts == 0 {
            return 0.0;
        }
        self.total_insts as f64 / secs / 1e6
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "exit={:?} insts={} wall={:.3}s mips={:.1}\n",
            self.exit,
            self.total_insts,
            self.wall.as_secs_f64(),
            self.mips()
        );
        if self.stages.len() > 1 {
            s.push_str(&format!("  stages: {}\n", self.stages.join(" -> ")));
        }
        if self.stage_reports.len() > 1 {
            for sr in &self.stage_reports {
                s.push_str(&format!(
                    "  stage {}: insts={} cycles={}\n",
                    sr.label, sr.insts, sr.cycles
                ));
            }
        }
        if let Some(sampling) = &self.sampling {
            s.push_str(&sampling.report());
        }
        for (i, (cyc, ins)) in self.per_hart.iter().enumerate() {
            s.push_str(&format!("  hart{}: mcycle={} minstret={}\n", i, cyc, ins));
        }
        if let Some(stats) = &self.engine_stats {
            if stats.block_entries > 0 {
                s.push_str(&format!(
                    "  dispatch: entries={} chain_hits={} chain_misses={} hit_rate={:.1}%\n",
                    stats.block_entries,
                    stats.chain_hits,
                    stats.chain_misses,
                    100.0 * stats.chain_hit_rate()
                ));
            }
        }
        for (k, v) in &self.model_stats {
            s.push_str(&format!("  {}={}\n", k, v));
        }
        if self.trace_dropped > 0 {
            s.push_str(&format!(
                "  trace: dropped={} (raise --trace capacity)\n",
                self.trace_dropped
            ));
        }
        if let Some(obs) = &self.obs {
            if !obs.is_empty() {
                s.push_str(&format!("  obs: events={} dropped={}", obs.events.len(), obs.dropped));
                if obs.dropped > 0 {
                    s.push_str(" (raise --obs-capacity)");
                }
                s.push('\n');
            }
        }
        s
    }
}

/// Build a `System` for `cfg` over existing guest DRAM (hand-off path).
fn system_over(cfg: &SimConfig, phys: Arc<PhysMem>) -> System {
    let model = memory_model_by_name(&cfg.memory, cfg).expect("validated");
    let mut sys = System::with_shared_phys(cfg.harts, phys, model);
    sys.set_line_shift(cfg.line_shift);
    sys.force_cold = cfg.no_l0;
    sys.bus.uart.echo = cfg.console;
    sys.timing = cfg.timing;
    if cfg.trace_capacity > 0 {
        sys.trace = Some(TraceCapture::new(cfg.trace_capacity));
    }
    if cfg.obs_enabled() {
        sys.obs = Some(Box::new(Obs::new(cfg.obs_capacity, cfg.trace_events, cfg.stats_every)));
    }
    sys.simctrl_state =
        simctrl_encoding_full(cfg.mode, &cfg.pipeline, &cfg.memory, cfg.line_shift);
    sys
}

/// Build the `System` described by `cfg` with fresh guest DRAM.
pub fn build_system(cfg: &SimConfig) -> System {
    system_over(cfg, Arc::new(PhysMem::new(DRAM_BASE, cfg.dram_bytes)))
}

/// Pack the current model configuration in the SIMCTRL CSR encoding
/// (engine field left at 0 = keep).
pub fn simctrl_encoding(pipeline: &str, memory: &str, line_shift: u32) -> u64 {
    let p = crate::pipeline::code_by_name(pipeline);
    let m: u64 = match memory {
        "atomic" => 1,
        "tlb" => 2,
        "cache" => 3,
        "mesi" => 4,
        _ => 0,
    };
    p | (m << 4) | (((1u64 << line_shift) & 0xfff) << 8)
}

/// Full SIMCTRL encoding including the engine-request field — what a
/// guest writes to trigger an engine-level hand-off (§3.5 extended).
pub fn simctrl_encoding_full(
    mode: EngineMode,
    pipeline: &str,
    memory: &str,
    line_shift: u32,
) -> u64 {
    simctrl_encoding(pipeline, memory, line_shift) | (mode.code() << SIMCTRL_ENGINE_SHIFT)
}

/// Decode a SIMCTRL write into a stage configuration: nonzero fields
/// override, zero fields keep the current value. Combinations that
/// violate Table 2 (the parallel engine requires the atomic memory model)
/// are sanitised rather than rejected — a guest-triggered hand-off must
/// not abort the simulation.
pub fn apply_simctrl_to_config(cfg: &mut SimConfig, value: u64) {
    if let Some(mode) = EngineMode::from_code((value >> SIMCTRL_ENGINE_SHIFT) & 0b111) {
        cfg.mode = mode;
    }
    if let Some(pipeline) = pipeline_name_by_code(value & 0b111) {
        cfg.pipeline = pipeline.into();
    }
    if let Some(memory) = memory_name_by_code((value >> 4) & 0b111) {
        cfg.memory = memory.into();
    }
    if let Some(shift) = line_shift_by_code(value) {
        cfg.line_shift = shift;
    }
    if cfg.mode == EngineMode::Parallel && cfg.memory != "atomic" {
        cfg.memory = "atomic".into();
    }
}

/// Human-readable stage label for reports (shared with the sampling
/// driver).
pub(crate) fn stage_label(cfg: &SimConfig) -> String {
    format!("{}/{}+{}", cfg.mode.as_str(), cfg.pipeline, cfg.memory)
}

/// Build an engine for `cfg` and boot it from a flat image.
pub fn build_engine(cfg: &SimConfig, image: &Image) -> Box<dyn ExecutionEngine> {
    let mut engine: Box<dyn ExecutionEngine> = match cfg.mode {
        EngineMode::Interp => {
            let sys = build_system(cfg);
            let mut eng = InterpEngine::new(sys);
            let entry = load_flat(&eng.sys, image);
            for h in &mut eng.harts {
                h.pc = entry;
            }
            Box::new(eng)
        }
        EngineMode::Lockstep => {
            let sys = build_system(cfg);
            let mut eng = FiberEngine::new(sys, &cfg.pipeline);
            eng.yield_per_instruction = cfg.naive_yield;
            eng.chaining = !cfg.no_chaining;
            eng.backend = cfg.backend;
            eng.dump_native = cfg.dump_native;
            let entry = load_flat(&eng.sys, image);
            eng.set_entry(entry);
            Box::new(eng)
        }
        EngineMode::Parallel => Box::new(ParallelEngine::from_image(cfg, image)),
        EngineMode::Sharded => {
            let phys = Arc::new(PhysMem::new(DRAM_BASE, cfg.dram_bytes));
            phys.load_image(image.base, &image.bytes);
            let mut eng = ShardedEngine::new(cfg.harts, cfg.shards, cfg.quantum, &cfg.pipeline, || {
                system_over(cfg, Arc::clone(&phys))
            });
            eng.set_backend(cfg.backend, cfg.dump_native);
            if cfg.adaptive_quantum {
                let (qmin, qmax) = cfg.quantum_bounds();
                eng.set_adaptive(qmin, qmax);
            }
            if cfg.repartition_every > 0 {
                eng.set_repartition(cfg.repartition_every);
            }
            eng.set_entry(image.entry);
            Box::new(eng)
        }
    };
    if cfg.profile {
        engine.set_profile(true);
    }
    engine
}

/// Build an engine for `cfg` warm-started from a snapshot (the second
/// half of an engine hand-off).
pub fn resume_engine(cfg: &SimConfig, snapshot: SystemSnapshot) -> Box<dyn ExecutionEngine> {
    let mut engine: Box<dyn ExecutionEngine> = match cfg.mode {
        EngineMode::Interp => {
            let sys = system_over(cfg, Arc::clone(&snapshot.phys));
            let mut eng = InterpEngine::new(sys);
            eng.resume(snapshot);
            Box::new(eng)
        }
        EngineMode::Lockstep => {
            let sys = system_over(cfg, Arc::clone(&snapshot.phys));
            let mut eng = FiberEngine::new(sys, &cfg.pipeline);
            eng.yield_per_instruction = cfg.naive_yield;
            eng.chaining = !cfg.no_chaining;
            eng.backend = cfg.backend;
            eng.dump_native = cfg.dump_native;
            eng.resume(snapshot);
            Box::new(eng)
        }
        EngineMode::Parallel => Box::new(ParallelEngine::from_snapshot(cfg, snapshot)),
        EngineMode::Sharded => {
            let phys = Arc::clone(&snapshot.phys);
            let mut eng = ShardedEngine::new(cfg.harts, cfg.shards, cfg.quantum, &cfg.pipeline, || {
                system_over(cfg, Arc::clone(&phys))
            });
            eng.set_backend(cfg.backend, cfg.dump_native);
            if cfg.adaptive_quantum {
                let (qmin, qmax) = cfg.quantum_bounds();
                eng.set_adaptive(qmin, qmax);
            }
            if cfg.repartition_every > 0 {
                eng.set_repartition(cfg.repartition_every);
            }
            eng.resume(snapshot);
            Box::new(eng)
        }
    };
    if cfg.profile {
        engine.set_profile(true);
    }
    engine
}

/// Run `image` to completion under `cfg`, performing engine hand-offs as
/// requested by the guest (SIMCTRL engine field) or by `--switch-at`, and
/// writing checkpoints at `--ckpt-every` boundaries / run end when
/// `--ckpt-out` is set.
pub fn run_image(cfg: &SimConfig, image: &Image) -> RunReport {
    cfg.validate().expect("invalid configuration");
    let stage = cfg.clone();
    let engine = build_engine(&stage, image);
    drive(cfg, stage, engine)
}

/// Resume a run from an on-disk checkpoint instead of booting an image.
/// The checkpoint is authoritative for guest topology (hart count, DRAM
/// geometry); `cfg` supplies everything else — models, engine mode,
/// budgets (`--max-insts` counts *total* retired instructions, including
/// those retired before the checkpoint was taken).
pub fn run_restored(cfg: &SimConfig, ckpt: crate::ckpt::Checkpoint) -> RunReport {
    let mut cfg = cfg.clone();
    cfg.harts = ckpt.num_harts();
    cfg.dram_bytes = ckpt.dram_size as usize;
    cfg.validate().expect("invalid configuration");
    let stage = cfg.clone();
    let engine = resume_engine(&stage, ckpt.into_snapshot());
    drive(&cfg, stage, engine)
}

/// A budget boundary hit inside the staged loop.
enum Boundary {
    /// Hand off to a new stage configuration; `Some` carries a guest
    /// SIMCTRL request, `None` means the `--switch-at` budget elapsed.
    Switch(Option<u64>),
    /// A `--ckpt-every` boundary: serialize and continue the same stage.
    Ckpt,
}

/// The staged run loop shared by [`run_image`] and [`run_restored`]: drive
/// the engine between budget boundaries, performing engine hand-offs and
/// periodic checkpoints, and attribute counters to the stage that produced
/// them.
fn drive(cfg: &SimConfig, mut stage: SimConfig, mut engine: Box<dyn ExecutionEngine>) -> RunReport {
    let t0 = Instant::now();
    // Observability accumulation: each engine instance is harvested once,
    // just before it is suspended or dropped, and the per-stage harvests
    // merge into one run-wide timeline/profile. Coordinator-side events
    // (hand-offs, checkpoint writes) land on their own track.
    let obs_on = cfg.obs_enabled();
    let mut obs_acc = Harvest::default();
    let mut trace_dropped = 0u64;
    let mut coord_seq = 0u64;
    let mut coord_event = |acc: &mut Harvest, cycle: u64, kind: EventKind| {
        if !cfg.trace_events {
            return;
        }
        coord_seq += 1;
        acc.events.push(Event {
            seq: coord_seq,
            host_ns: t0.elapsed().as_nanos() as u64,
            cycle,
            hart: TRACK_COORDINATOR,
            kind,
        });
    };
    let mut stages = vec![stage_label(&stage)];
    let mut stage_reports: Vec<StageReport> = Vec::new();
    let mut acc_stats = EngineStats::default();
    let mut switch_at = stage.switch_at;
    // Per-stage attribution baselines (stat hygiene): hart clocks persist
    // across hand-offs, so stage counts are deltas against these.
    let (mut stage_cycles0, mut stage_insts0) = hart_totals(engine.as_ref());
    let mut stage_engine_stats = EngineStats::default();
    let mut stage_model_stats: Vec<(&'static str, u64)> = Vec::new();
    // Periodic checkpoint schedule (absolute budget-progress marks).
    let mut ckpt_seq = 0u32;
    let mut next_ckpt = match (&cfg.ckpt_out, cfg.ckpt_every) {
        (Some(_), Some(every)) => Some(engine.budget_progress().saturating_add(every)),
        _ => None,
    };

    let exit = loop {
        // Budgets are in the unit the engine's `run` consumes: total
        // retired instructions for serial engines, per-hart for the
        // parallel engine (`budget_progress` reports the same unit). The
        // nearest boundary — run end, `--switch-at`, `--ckpt-every` —
        // bounds this leg and decides what its `StepLimit` means.
        let progress = engine.budget_progress();
        let mut budget = cfg.max_insts.saturating_sub(progress);
        let mut bounded_by: Option<Boundary> = None;
        if let Some(at) = switch_at {
            let to_switch = at.saturating_sub(progress);
            if to_switch < budget {
                budget = to_switch;
                bounded_by = Some(Boundary::Switch(None));
            }
        }
        if let Some(at) = next_ckpt {
            let to_ckpt = at.saturating_sub(progress);
            if to_ckpt < budget {
                budget = to_ckpt;
                bounded_by = Some(Boundary::Ckpt);
            }
        }
        // Decide what the stop means; anything other than a boundary ends
        // the run.
        let boundary = match engine.run(budget) {
            ExitReason::SwitchRequest(value) => Boundary::Switch(Some(value)),
            ExitReason::StepLimit => match bounded_by {
                Some(b) => b,
                None => break ExitReason::StepLimit,
            },
            other => break other,
        };
        match boundary {
            Boundary::Switch(trigger) => {
                // Close the finishing stage's attributed counters.
                stage_engine_stats.merge(&engine.stats());
                merge_model_stats(&mut stage_model_stats, &engine.model_stats());
                let (cycles1, insts1) = hart_totals(engine.as_ref());
                stage_reports.push(StageReport {
                    label: stages.last().expect("stages is never empty").clone(),
                    insts: insts1 - stage_insts0,
                    cycles: cycles1 - stage_cycles0,
                    model_stats: std::mem::take(&mut stage_model_stats),
                    engine_stats: std::mem::take(&mut stage_engine_stats),
                });
                // Decode the next stage's configuration.
                match trigger {
                    Some(value) => apply_simctrl_to_config(&mut stage, value),
                    None => {
                        let (mode, pipeline, memory) =
                            stage.switch_target().expect("validated");
                        stage.mode = mode;
                        stage.pipeline = pipeline;
                        stage.memory = memory;
                    }
                }
                // The hand-off itself is identical for both triggers.
                switch_at = None;
                acc_stats.merge(&engine.stats());
                if obs_on {
                    let cycle = engine.per_hart().iter().map(|&(c, _)| c).max().unwrap_or(0);
                    coord_event(
                        &mut obs_acc,
                        cycle,
                        EventKind::EngineHandoff { value: trigger.unwrap_or(0) },
                    );
                    if let Some(h) = engine.take_obs() {
                        obs_acc.merge(h);
                    }
                }
                trace_dropped += engine.trace_dropped().unwrap_or(0);
                let snapshot = engine.suspend();
                engine = resume_engine(&stage, snapshot);
                stages.push(stage_label(&stage));
                let (cycles, insts) = hart_totals(engine.as_ref());
                stage_cycles0 = cycles;
                stage_insts0 = insts;
                // `budget_progress` units can change across engines
                // (per-hart for parallel, total for serial): re-anchor the
                // periodic-checkpoint schedule at the hand-off point so a
                // unit jump cannot fire checkpoints early or late.
                if next_ckpt.is_some() {
                    next_ckpt =
                        cfg.ckpt_every.map(|n| engine.budget_progress().saturating_add(n));
                }
            }
            Boundary::Ckpt => {
                // Serialize the guest and continue the same stage over the
                // same DRAM. The respawned engine starts with cold
                // acceleration state but a fresh memory model too, so its
                // counters are folded into the stage's accumulator here.
                stage_engine_stats.merge(&engine.stats());
                merge_model_stats(&mut stage_model_stats, &engine.model_stats());
                acc_stats.merge(&engine.stats());
                let ckpt_cycle = engine.per_hart().iter().map(|&(c, _)| c).max().unwrap_or(0);
                if obs_on {
                    if let Some(h) = engine.take_obs() {
                        obs_acc.merge(h);
                    }
                }
                trace_dropped += engine.trace_dropped().unwrap_or(0);
                let snapshot = engine.suspend();
                ckpt_seq += 1;
                coord_event(
                    &mut obs_acc,
                    ckpt_cycle,
                    EventKind::CheckpointWrite { seq: ckpt_seq as u64 },
                );
                let base = cfg.ckpt_out.as_deref().expect("ckpt boundary implies --ckpt-out");
                let path = format!("{}.{}", base, ckpt_seq);
                let ckpt = crate::ckpt::Checkpoint::from_snapshot(&snapshot);
                if let Err(e) = ckpt.save(std::path::Path::new(&path)) {
                    // A full disk must not abort a long simulation: the run
                    // continues, only the checkpoint is lost.
                    eprintln!("warning: failed to write checkpoint {}: {}", path, e);
                }
                engine = resume_engine(&stage, snapshot);
                next_ckpt =
                    cfg.ckpt_every.map(|n| engine.budget_progress().saturating_add(n));
            }
        }
    };
    let wall = t0.elapsed();
    acc_stats.merge(&engine.stats());
    // Close the final stage.
    stage_engine_stats.merge(&engine.stats());
    merge_model_stats(&mut stage_model_stats, &engine.model_stats());
    let (cycles1, insts1) = hart_totals(engine.as_ref());
    let final_model_stats = stage_model_stats.clone();
    stage_reports.push(StageReport {
        label: stages.last().expect("stages is never empty").clone(),
        insts: insts1 - stage_insts0,
        cycles: cycles1 - stage_cycles0,
        model_stats: stage_model_stats,
        engine_stats: stage_engine_stats,
    });
    // Harvest the final engine. The terminal checkpoint is written after
    // the report is assembled (suspend consumes the engine), so its event
    // is announced here, gated on the same `--ckpt-out` condition.
    if obs_on {
        if let Some(h) = engine.take_obs() {
            obs_acc.merge(h);
        }
        if cfg.ckpt_out.is_some() {
            let cycle = engine.per_hart().iter().map(|&(c, _)| c).max().unwrap_or(0);
            coord_event(&mut obs_acc, cycle, EventKind::CheckpointWrite { seq: 0 });
        }
        obs_acc.sort_events();
    }
    trace_dropped += engine.trace_dropped().unwrap_or(0);
    let report = RunReport {
        exit,
        wall,
        total_insts: engine.total_instret(),
        per_hart: engine.per_hart(),
        console: engine.console(),
        model_stats: final_model_stats,
        engine_stats: Some(acc_stats),
        stages,
        stage_reports,
        sampling: None,
        obs: obs_on.then_some(obs_acc),
        trace_dropped,
    };
    // Terminal checkpoint: `--ckpt-out` always records the end-of-run
    // state at the base path (the report is assembled first — suspending
    // consumes the engine).
    if let Some(base) = &cfg.ckpt_out {
        let snapshot = engine.suspend();
        let ckpt = crate::ckpt::Checkpoint::from_snapshot(&snapshot);
        if let Err(e) = ckpt.save(std::path::Path::new(base)) {
            // The completed run's report must survive a write failure.
            eprintln!("warning: failed to write checkpoint {}: {}", base, e);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::*;
    use crate::mem::DRAM_BASE;

    fn countdown(n: i64) -> Image {
        let mut a = Assembler::new(DRAM_BASE);
        a.li(A0, n);
        a.li(A1, 0);
        let top = a.here();
        a.add(A1, A1, A0);
        a.addi(A0, A0, -1);
        a.bnez(A0, top);
        a.mv(A0, A1);
        a.li(A7, 93);
        a.ecall();
        a.finish()
    }

    #[test]
    fn all_modes_agree_on_result() {
        let img = countdown(99);
        let want = ExitReason::Exited(99 * 100 / 2);
        for mode in ["interp", "lockstep", "parallel"] {
            let mut cfg = SimConfig::default();
            cfg.set("mode", mode).unwrap();
            cfg.set("memory", "atomic").unwrap();
            cfg.pipeline = "atomic".into();
            let report = run_image(&cfg, &img);
            assert_eq!(report.exit, want, "mode {}", mode);
        }
    }

    #[test]
    fn model_matrix_smoke() {
        let img = countdown(25);
        for memory in ["atomic", "tlb", "cache", "mesi"] {
            for pipeline in ["atomic", "simple", "inorder", "o3"] {
                let mut cfg = SimConfig::default();
                cfg.set("memory", memory).unwrap();
                cfg.pipeline = pipeline.into();
                let report = run_image(&cfg, &img);
                assert_eq!(
                    report.exit,
                    ExitReason::Exited(325),
                    "pipeline={} memory={}",
                    pipeline,
                    memory
                );
            }
        }
    }

    #[test]
    fn timing_models_order_sanely() {
        // For the same program: inorder+mesi >= simple+cache >= simple+atomic
        // in simulated cycles.
        let img = countdown(500);
        let cycles = |pipeline: &str, memory: &str| {
            let mut cfg = SimConfig::default();
            cfg.pipeline = pipeline.into();
            cfg.set("memory", memory).unwrap();
            let r = run_image(&cfg, &img);
            r.per_hart[0].0
        };
        let base = cycles("simple", "atomic");
        let cache = cycles("simple", "cache");
        let full = cycles("inorder", "mesi");
        assert!(cache >= base, "cache {} >= atomic {}", cache, base);
        assert!(full >= cache, "inorder+mesi {} >= simple+cache {}", full, cache);
    }

    #[test]
    fn models_report_lists_tables() {
        let r = models_report();
        assert!(r.contains("InOrder"));
        assert!(r.contains("O3"), "registry-derived table lists the o3 model");
        assert!(r.contains("MESI"));
        assert!(r.contains("Lockstep execution required"));
        assert!(r.contains("lockstep"), "engine inventory must be listed");
        assert!(r.contains("--switch-at"));
    }

    #[test]
    fn simctrl_encoding_roundtrip() {
        let v = simctrl_encoding("inorder", "mesi", 6);
        assert_eq!(v & 0b111, 3);
        assert_eq!(simctrl_encoding("o3", "mesi", 6) & 0b111, 4);
        assert_eq!(simctrl_encoding("out-of-order", "mesi", 6) & 0b111, 4, "aliases encode too");
        assert_eq!((v >> 4) & 0b111, 4);
        assert_eq!((v >> 8) & 0xfff, 64);
        assert_eq!((v >> SIMCTRL_ENGINE_SHIFT) & 0b111, 0, "plain encoding keeps the engine");
        let full = simctrl_encoding_full(EngineMode::Parallel, "atomic", "atomic", 6);
        assert_eq!((full >> SIMCTRL_ENGINE_SHIFT) & 0b111, 3);
    }

    #[test]
    fn mips_guards_zero_wall_clock() {
        let report = RunReport {
            exit: ExitReason::Exited(0),
            wall: std::time::Duration::ZERO,
            total_insts: 1_000_000,
            per_hart: vec![(0, 1_000_000)],
            console: String::new(),
            model_stats: Vec::new(),
            engine_stats: None,
            stages: vec!["lockstep/simple+atomic".into()],
            stage_reports: Vec::new(),
            sampling: None,
            obs: None,
            trace_dropped: 0,
        };
        assert_eq!(report.mips(), 0.0, "zero wall clock must not produce inf");
        assert!(report.summary().contains("mips=0.0"));
        let empty = RunReport { total_insts: 0, wall: std::time::Duration::from_secs(1), ..report };
        assert_eq!(empty.mips(), 0.0);
    }

    #[test]
    fn switch_at_hands_off_to_switch_to_target() {
        let img = countdown(2_000);
        let mut cfg = SimConfig::default();
        cfg.set("mode", "parallel").unwrap();
        cfg.pipeline = "atomic".into();
        cfg.set("switch-at", "1000").unwrap();
        let report = run_image(&cfg, &img);
        assert_eq!(report.exit, ExitReason::Exited(2_000 * 2_001 / 2));
        assert_eq!(report.stages.len(), 2, "exactly one hand-off: {:?}", report.stages);
        assert_eq!(report.stages[0], "parallel/atomic+atomic");
        assert_eq!(report.stages[1], "lockstep/inorder+mesi");
        // The measured stage runs under MESI: model stats must be present.
        assert!(!report.model_stats.is_empty());
    }

    #[test]
    fn stage_reports_attribute_counters_per_stage() {
        let img = countdown(2_000);
        let mut cfg = SimConfig::default();
        cfg.set("switch-at", "1000").unwrap();
        cfg.set("switch-to", "lockstep:inorder:cache").unwrap();
        let report = run_image(&cfg, &img);
        assert_eq!(report.exit, ExitReason::Exited(2_000 * 2_001 / 2));
        assert_eq!(report.stage_reports.len(), 2, "one report per stage");
        let (first, second) = (&report.stage_reports[0], &report.stage_reports[1]);
        assert_eq!(first.label, report.stages[0]);
        assert_eq!(second.label, report.stages[1]);
        // Stage instruction counts partition the run exactly.
        assert_eq!(first.insts + second.insts, report.total_insts);
        assert!(first.insts >= 1000, "fast-forward covered its budget: {}", first.insts);
        assert!(second.insts > 0, "measured stage retired the rest");
        // The first stage ran the atomic model: no cache counters may leak
        // into it; the second ran the cache model and must have them (the
        // countdown loop is register-only, so the I-side is the live one).
        assert!(first.model_stats.is_empty(), "{:?}", first.model_stats);
        assert!(second.model_stats.iter().any(|&(k, v)| k == "icache_cold_accesses" && v > 0));
        // RunReport's model_stats belong to the final stage alone.
        assert_eq!(report.model_stats, second.model_stats);
        // summary() prints per-stage attribution for staged runs.
        assert!(report.summary().contains("stage lockstep/simple+atomic:"));
    }

    #[test]
    fn merge_model_stats_sums_by_key() {
        let mut acc = vec![("hits", 3), ("misses", 1)];
        merge_model_stats(&mut acc, &[("misses", 2), ("evictions", 5)]);
        assert_eq!(acc, vec![("hits", 3), ("misses", 3), ("evictions", 5)]);
        let mut empty: Vec<(&'static str, u64)> = Vec::new();
        merge_model_stats(&mut empty, &[("hits", 1)]);
        assert_eq!(empty, vec![("hits", 1)]);
    }

    #[test]
    fn periodic_checkpoints_do_not_perturb_the_run() {
        // inorder+atomic: cycle costs are translation-baked and the cold
        // path charges nothing, so suspend/serialize/resume must be fully
        // timing-neutral. (Timing memory models legitimately diverge at a
        // boundary — simulated-cache residue is dropped and re-warmed.)
        let img = countdown(3_000);
        let mut plain = SimConfig::default();
        plain.pipeline = "inorder".into();
        let a = run_image(&plain, &img);

        let base = std::env::temp_dir()
            .join(format!("r2vm-coord-ckpt-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let mut ck = plain.clone();
        ck.ckpt_out = Some(base.clone());
        ck.ckpt_every = Some(4_000); // two boundaries across ~9k insts
        let b = run_image(&ck, &img);

        assert_eq!(a.exit, b.exit);
        assert_eq!(a.per_hart, b.per_hart, "suspend/serialize/resume must be timing-neutral");
        // Periodic files <base>.1.. plus the terminal <base> exist and load.
        let terminal = crate::ckpt::Checkpoint::load(std::path::Path::new(&base)).unwrap();
        assert_eq!(terminal.total_instret(), b.total_insts);
        assert_eq!(terminal.exit, Some(3_000 * 3_001 / 2));
        let first = crate::ckpt::Checkpoint::load(std::path::Path::new(&format!("{}.1", base)))
            .expect("first periodic checkpoint written");
        assert!(first.total_instret() >= 4_000);
        assert!(first.total_instret() < b.total_insts);
        // Restoring the first periodic checkpoint finishes with identical
        // architectural state.
        let c = run_restored(&plain, first);
        assert_eq!(c.exit, a.exit);
        assert_eq!(c.per_hart, a.per_hart, "restore must reproduce the unbroken run");
        // Cleanup.
        let mut k = 1;
        while std::fs::remove_file(format!("{}.{}", base, k)).is_ok() {
            k += 1;
        }
        std::fs::remove_file(&base).ok();
    }
}
