//! SBI (Supervisor Binary Interface) emulation (paper §3.5:
//! "for supervisor-level [simulation], SBI calls are emulated").
//!
//! Implements the legacy extensions plus the base/TIME/sPI/SRST extensions
//! — enough to run bare-metal SMP workloads and simple kernels. IPIs are
//! posted into `System::ipi` and folded into the target hart's `mip` by the
//! execution engine at its next interrupt poll (block end, §3.3.2).

use super::hart::Hart;
use super::System;
use crate::isa::csr::{IRQ_SSIP, IRQ_STIP};

// Legacy extension IDs (a7).
const LEGACY_SET_TIMER: u64 = 0;
const LEGACY_CONSOLE_PUTCHAR: u64 = 1;
const LEGACY_CONSOLE_GETCHAR: u64 = 2;
const LEGACY_CLEAR_IPI: u64 = 3;
const LEGACY_SEND_IPI: u64 = 4;
const LEGACY_SHUTDOWN: u64 = 8;

// Modern extension IDs.
const EXT_BASE: u64 = 0x10;
const EXT_TIME: u64 = 0x54494D45;
const EXT_SPI: u64 = 0x735049;
const EXT_SRST: u64 = 0x53525354;

/// riscv-tests-style "proxy" exit: `a7 == 93` is treated as exit(a0) in
/// SBI mode so bare-metal M-mode workloads can terminate cleanly.
const PROXY_EXIT: u64 = 93;

const SBI_SUCCESS: u64 = 0;
const SBI_ERR_NOT_SUPPORTED: u64 = (-2i64) as u64;

/// Handle an ecall as an SBI call. Mutates hart registers (a0/a1 return
/// values per the SBI calling convention). Returns `true` if handled (the
/// engine then resumes at the instruction after the ecall).
pub fn handle_sbi(hart: &mut Hart, sys: &mut System) -> bool {
    let eid = hart.reg(17); // a7
    let fid = hart.reg(16); // a6
    let a0 = hart.reg(10);

    match eid {
        LEGACY_SET_TIMER => {
            sys.bus.clint.mtimecmp[hart.id] = a0;
            hart.mip &= !IRQ_STIP;
            hart.set_reg(10, 0);
            true
        }
        LEGACY_CONSOLE_PUTCHAR => {
            sys.bus.uart.write(0, a0);
            hart.set_reg(10, 0);
            true
        }
        LEGACY_CONSOLE_GETCHAR => {
            hart.set_reg(10, u64::MAX); // no input
            true
        }
        LEGACY_CLEAR_IPI => {
            hart.mip &= !IRQ_SSIP;
            hart.set_reg(10, 0);
            true
        }
        LEGACY_SEND_IPI => {
            // Deviation from the legacy ABI (documented in DESIGN.md):
            // a0 is the hart mask *value*, not a pointer to it.
            post_ipis(sys, a0, IRQ_SSIP);
            hart.set_reg(10, 0);
            true
        }
        LEGACY_SHUTDOWN => {
            sys.exit = Some(0);
            true
        }
        PROXY_EXIT => {
            sys.exit = Some(a0);
            true
        }
        EXT_BASE => {
            let v = match fid {
                0 => 0x0100_0000u64, // spec version 1.0
                1 => 0x52_32_56_4d,  // impl id "R2VM"
                2 => 1,              // impl version
                3 => {
                    // probe_extension(a0)
                    let known = matches!(a0, EXT_BASE | EXT_TIME | EXT_SPI | EXT_SRST)
                        || a0 <= LEGACY_SHUTDOWN;
                    hart.set_reg(10, SBI_SUCCESS);
                    hart.set_reg(11, known as u64);
                    return true;
                }
                4 | 5 | 6 => 0, // mvendorid/marchid/mimpid
                _ => {
                    hart.set_reg(10, SBI_ERR_NOT_SUPPORTED);
                    return true;
                }
            };
            hart.set_reg(10, SBI_SUCCESS);
            hart.set_reg(11, v);
            true
        }
        EXT_TIME => {
            if fid == 0 {
                sys.bus.clint.mtimecmp[hart.id] = a0;
                hart.mip &= !IRQ_STIP;
                hart.set_reg(10, SBI_SUCCESS);
                hart.set_reg(11, 0);
                true
            } else {
                hart.set_reg(10, SBI_ERR_NOT_SUPPORTED);
                true
            }
        }
        EXT_SPI => {
            if fid == 0 {
                // send_ipi(hart_mask, hart_mask_base)
                let base = hart.reg(11);
                let mask = if base == u64::MAX { a0 } else { a0 << base };
                post_ipis(sys, mask, IRQ_SSIP);
                hart.set_reg(10, SBI_SUCCESS);
                hart.set_reg(11, 0);
                true
            } else {
                hart.set_reg(10, SBI_ERR_NOT_SUPPORTED);
                true
            }
        }
        EXT_SRST => {
            sys.exit = Some(hart.reg(11)); // reset reason as exit code
            true
        }
        _ => false,
    }
}

fn post_ipis(sys: &mut System, mask: u64, bits: u64) {
    for h in 0..sys.num_harts {
        if mask & (1 << h) != 0 {
            sys.ipi[h] |= bits;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Hart, System) {
        (Hart::new(0), System::new(2, 1 << 20))
    }

    #[test]
    fn putchar_and_shutdown() {
        let (mut h, mut s) = setup();
        h.set_reg(17, LEGACY_CONSOLE_PUTCHAR);
        h.set_reg(10, b'Z' as u64);
        assert!(handle_sbi(&mut h, &mut s));
        assert_eq!(s.bus.uart.output, vec![b'Z']);
        h.set_reg(17, LEGACY_SHUTDOWN);
        assert!(handle_sbi(&mut h, &mut s));
        assert_eq!(s.exit, Some(0));
    }

    #[test]
    fn set_timer_programs_clint() {
        let (mut h, mut s) = setup();
        h.set_reg(17, LEGACY_SET_TIMER);
        h.set_reg(10, 12345);
        h.mip = IRQ_STIP;
        assert!(handle_sbi(&mut h, &mut s));
        assert_eq!(s.bus.clint.mtimecmp[0], 12345);
        assert_eq!(h.mip & IRQ_STIP, 0, "pending STIP must be cleared");
    }

    #[test]
    fn ipi_posts_to_target() {
        let (mut h, mut s) = setup();
        h.set_reg(17, LEGACY_SEND_IPI);
        h.set_reg(10, 0b10); // hart 1
        assert!(handle_sbi(&mut h, &mut s));
        assert_eq!(s.ipi[1], IRQ_SSIP);
        assert_eq!(s.ipi[0], 0);
    }

    #[test]
    fn proxy_exit() {
        let (mut h, mut s) = setup();
        h.set_reg(17, 93);
        h.set_reg(10, 7);
        assert!(handle_sbi(&mut h, &mut s));
        assert_eq!(s.exit, Some(7));
    }

    #[test]
    fn base_extension_probe() {
        let (mut h, mut s) = setup();
        h.set_reg(17, EXT_BASE);
        h.set_reg(16, 3);
        h.set_reg(10, EXT_TIME);
        assert!(handle_sbi(&mut h, &mut s));
        assert_eq!(h.reg(11), 1);
    }

    #[test]
    fn unknown_extension_unhandled() {
        let (mut h, mut s) = setup();
        h.set_reg(17, 0xdeadbeef);
        assert!(!handle_sbi(&mut h, &mut s));
    }
}
