//! The engine hand-off snapshot (paper §3.5 extended to engine-level
//! switching).
//!
//! A [`SystemSnapshot`] is everything the *guest* can observe: hart
//! architectural state (registers, CSR file, privilege, counters, WFI
//! flag), guest DRAM (shared by `Arc`, so a hand-off never copies it),
//! pending inter-processor interrupts, and device state (CLINT timers and
//! software-interrupt bits, accumulated console output, the exit latch).
//!
//! What it deliberately does *not* carry is engine residue: DBT code
//! caches, fiber continuations, L0 cache/TLB contents, and memory-model
//! replacement state are all rebuilt cold by the next engine. Dropping
//! them is always architecturally safe (caches and translations are pure
//! acceleration state), and it is exactly the "memory-model residue" flush
//! the SIMCTRL model-switch path already performs.

use super::{EcallMode, Hart, System};
use crate::analytics::trace::TraceCapture;
use crate::mem::PhysMem;
use std::sync::Arc;

/// Guest-visible system state in transit between two execution engines.
pub struct SystemSnapshot {
    /// Architectural hart state; `pending` cycles are folded into `cycle`
    /// and side-effect latches are cleared before capture.
    pub harts: Vec<Hart>,
    /// Guest DRAM — shared, not copied. The resuming engine's `System`
    /// must be built over this same allocation.
    pub phys: Arc<PhysMem>,
    /// Pending inter-processor interrupt bits per hart.
    pub ipi: Vec<u64>,
    /// CLINT software-interrupt bits per hart.
    pub msip: Vec<bool>,
    /// CLINT timer compare registers per hart.
    pub mtimecmp: Vec<u64>,
    /// UART console output accumulated so far.
    pub console: Vec<u8>,
    /// Exit latch (SBI shutdown / proxy exit / SIMIO tohost).
    pub exit: Option<u64>,
    pub ecall_mode: EcallMode,
    /// Program break / mmap bump pointer for user-level emulation.
    pub brk: u64,
    pub mmap_top: u64,
    /// Analytics trace capture in flight, if enabled.
    pub trace: Option<TraceCapture>,
}

impl SystemSnapshot {
    /// Fold pending cycles into each hart's committed clock and clear
    /// side-effect latches — the normalization every engine performs on
    /// its hart vector before snapshotting it.
    pub fn normalize_harts(harts: &mut [Hart]) {
        for hart in harts {
            hart.cycle += std::mem::take(&mut hart.pending);
            hart.effects.clear();
        }
    }

    /// Capture guest-visible state from an engine's hart vector + system.
    /// The engine must already be at an architecturally consistent point
    /// (PCs written back, no partially-executed instruction).
    pub fn capture(mut harts: Vec<Hart>, sys: &mut System) -> SystemSnapshot {
        Self::normalize_harts(&mut harts);
        SystemSnapshot {
            harts,
            phys: Arc::clone(&sys.phys),
            ipi: sys.ipi.clone(),
            msip: sys.bus.clint.msip.clone(),
            mtimecmp: sys.bus.clint.mtimecmp.clone(),
            console: std::mem::take(&mut sys.bus.uart.output),
            exit: sys.exit.or(sys.bus.simio.exit_code),
            ecall_mode: sys.ecall_mode,
            brk: sys.brk,
            mmap_top: sys.mmap_top,
            trace: sys.trace.take(),
        }
    }

    /// Install the snapshot into a freshly-built `System` over the same
    /// `PhysMem`, returning the hart vector for the engine. The target
    /// system starts with cold L0s/code caches, so no stale translation
    /// state can survive the hand-off.
    pub fn install(self, sys: &mut System) -> Vec<Hart> {
        assert!(
            Arc::ptr_eq(&self.phys, &sys.phys),
            "snapshot must be resumed over its own guest DRAM"
        );
        assert_eq!(self.harts.len(), sys.num_harts, "hart count is fixed across hand-offs");
        sys.ipi = self.ipi;
        sys.bus.clint.msip = self.msip;
        sys.bus.clint.mtimecmp = self.mtimecmp;
        sys.bus.uart.output = self.console;
        sys.exit = self.exit;
        sys.ecall_mode = self.ecall_mode;
        sys.brk = self.brk;
        sys.mmap_top = self.mmap_top;
        if self.trace.is_some() {
            sys.trace = self.trace;
        }
        self.harts
    }

    /// Total retired instructions across all harts at capture time.
    pub fn total_instret(&self) -> u64 {
        self.harts.iter().map(|h| h.instret).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::DRAM_BASE;

    #[test]
    fn capture_folds_pending_and_install_round_trips() {
        let mut sys = System::new(2, 1 << 20);
        sys.ipi[1] = 2;
        sys.bus.clint.mtimecmp[0] = 777;
        sys.bus.uart.output = b"boot".to_vec();
        let mut harts: Vec<Hart> = (0..2).map(Hart::new).collect();
        harts[0].pc = DRAM_BASE + 64;
        harts[0].cycle = 10;
        harts[0].pending = 5;
        harts[0].regs[10] = 0xabcd;
        harts[1].instret = 42;

        let snap = SystemSnapshot::capture(harts, &mut sys);
        assert_eq!(snap.harts[0].cycle, 15);
        assert_eq!(snap.harts[0].pending, 0);
        assert_eq!(snap.total_instret(), 42);
        assert_eq!(snap.console, b"boot");

        // Resume over the same DRAM in a fresh system.
        let mut sys2 = System::with_shared_phys(
            2,
            Arc::clone(&snap.phys),
            Box::new(crate::mem::AtomicModel),
        );
        let harts = snap.install(&mut sys2);
        assert_eq!(harts[0].pc, DRAM_BASE + 64);
        assert_eq!(harts[0].regs[10], 0xabcd);
        assert_eq!(sys2.ipi[1], 2);
        assert_eq!(sys2.bus.clint.mtimecmp[0], 777);
        assert_eq!(sys2.bus.uart.output, b"boot");
    }

    #[test]
    #[should_panic(expected = "own guest DRAM")]
    fn install_rejects_foreign_dram() {
        let mut sys = System::new(1, 1 << 20);
        let snap = SystemSnapshot::capture(vec![Hart::new(0)], &mut sys);
        let mut other = System::new(1, 1 << 20);
        let _ = snap.install(&mut other);
    }
}
