//! User-level Linux syscall emulation (paper §3.5: "for user-level
//! simulation, Linux syscalls are emulated").
//!
//! Implements the subset needed by the built-in workloads and simple
//! statically-linked programs: console I/O, exit, brk, and benign stubs for
//! common process-setup calls. RISC-V Linux syscall numbers.

use super::exec::{read_mem, write_mem};
use super::hart::Hart;
use super::System;
use crate::isa::MemWidth;

pub const SYS_GETCWD: u64 = 17;
pub const SYS_FCNTL: u64 = 25;
pub const SYS_IOCTL: u64 = 29;
pub const SYS_CLOSE: u64 = 57;
pub const SYS_LSEEK: u64 = 62;
pub const SYS_READ: u64 = 63;
pub const SYS_WRITE: u64 = 64;
pub const SYS_WRITEV: u64 = 66;
pub const SYS_READLINKAT: u64 = 78;
pub const SYS_FSTAT: u64 = 80;
pub const SYS_EXIT: u64 = 93;
pub const SYS_EXIT_GROUP: u64 = 94;
pub const SYS_SET_TID_ADDRESS: u64 = 96;
pub const SYS_CLOCK_GETTIME: u64 = 113;
pub const SYS_SCHED_YIELD: u64 = 124;
pub const SYS_TIMES: u64 = 153;
pub const SYS_UNAME: u64 = 160;
pub const SYS_GETPID: u64 = 172;
pub const SYS_GETUID: u64 = 174;
pub const SYS_BRK: u64 = 214;
pub const SYS_MUNMAP: u64 = 215;
pub const SYS_MMAP: u64 = 222;

const ENOSYS: u64 = (-38i64) as u64;
const EBADF: u64 = (-9i64) as u64;

/// Handle an ecall from U-mode as a Linux syscall. Returns `true` if the
/// call was emulated (a0 holds the return value).
pub fn handle_syscall(hart: &mut Hart, sys: &mut System) -> bool {
    let nr = hart.reg(17);
    let (a0, a1, a2) = (hart.reg(10), hart.reg(11), hart.reg(12));
    let ret: u64 = match nr {
        SYS_EXIT | SYS_EXIT_GROUP => {
            sys.exit = Some(a0);
            0
        }
        SYS_WRITE => {
            if a0 == 1 || a0 == 2 {
                let mut written = 0;
                for i in 0..a2 {
                    match read_mem(hart, sys, a1 + i, MemWidth::B) {
                        Ok(b) => {
                            sys.bus.uart.write(0, b);
                            written += 1;
                        }
                        Err(_) => break,
                    }
                }
                written
            } else {
                EBADF
            }
        }
        SYS_WRITEV => {
            // iovec array at a1, count a2
            let mut total = 0u64;
            for i in 0..a2 {
                let base = match read_mem(hart, sys, a1 + i * 16, MemWidth::D) {
                    Ok(v) => v,
                    Err(_) => break,
                };
                let len = match read_mem(hart, sys, a1 + i * 16 + 8, MemWidth::D) {
                    Ok(v) => v,
                    Err(_) => break,
                };
                for k in 0..len {
                    if let Ok(b) = read_mem(hart, sys, base + k, MemWidth::B) {
                        sys.bus.uart.write(0, b);
                        total += 1;
                    }
                }
            }
            total
        }
        SYS_READ => 0, // EOF
        SYS_BRK => {
            if a0 == 0 {
                sys.brk
            } else {
                sys.brk = a0;
                sys.brk
            }
        }
        SYS_MMAP => {
            // Anonymous-mapping bump allocator.
            let len = (a1 + 0xfff) & !0xfff;
            let addr = sys.mmap_top;
            sys.mmap_top += len;
            addr
        }
        SYS_MUNMAP => 0,
        SYS_CLOCK_GETTIME => {
            // timespec{sec, nsec} derived from the cycle counter @1GHz.
            let cycles = hart.now();
            let sec = cycles / 1_000_000_000;
            let nsec = cycles % 1_000_000_000;
            if write_mem(hart, sys, a1, MemWidth::D, sec).is_err()
                || write_mem(hart, sys, a1 + 8, MemWidth::D, nsec).is_err()
            {
                (-14i64) as u64 // EFAULT
            } else {
                0
            }
        }
        SYS_TIMES => hart.now(),
        SYS_UNAME => {
            // struct utsname: 6 fields x 65 bytes
            let fields = ["Linux", "r2vm", "6.0.0-r2vm", "r2vm-repro", "riscv64", ""];
            let mut ok = true;
            for (i, f) in fields.iter().enumerate() {
                let base = a0 + (i as u64) * 65;
                for (k, b) in f.bytes().chain(std::iter::once(0)).enumerate() {
                    if write_mem(hart, sys, base + k as u64, MemWidth::B, b as u64).is_err() {
                        ok = false;
                        break;
                    }
                }
            }
            if ok {
                0
            } else {
                (-14i64) as u64
            }
        }
        SYS_GETPID => 1,
        SYS_GETUID => 0,
        SYS_SET_TID_ADDRESS => 1,
        SYS_SCHED_YIELD => 0,
        SYS_CLOSE | SYS_LSEEK | SYS_FCNTL | SYS_IOCTL => 0,
        SYS_FSTAT | SYS_READLINKAT | SYS_GETCWD => ENOSYS,
        _ => ENOSYS,
    };
    hart.set_reg(10, ret);
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::DRAM_BASE;

    fn setup() -> (Hart, System) {
        let mut h = Hart::new(0);
        h.prv = crate::isa::csr::Priv::User;
        (h, System::new(1, 1 << 20))
    }

    #[test]
    fn write_to_stdout() {
        let (mut h, mut s) = setup();
        s.phys.load_image(DRAM_BASE + 0x100, b"hello");
        h.set_reg(17, SYS_WRITE);
        h.set_reg(10, 1);
        h.set_reg(11, DRAM_BASE + 0x100);
        h.set_reg(12, 5);
        assert!(handle_syscall(&mut h, &mut s));
        assert_eq!(h.reg(10), 5);
        assert_eq!(s.bus.uart.output_str(), "hello");
    }

    #[test]
    fn exit_sets_code() {
        let (mut h, mut s) = setup();
        h.set_reg(17, SYS_EXIT);
        h.set_reg(10, 3);
        handle_syscall(&mut h, &mut s);
        assert_eq!(s.exit, Some(3));
    }

    #[test]
    fn brk_and_mmap() {
        let (mut h, mut s) = setup();
        s.brk = DRAM_BASE + 0x10000;
        s.mmap_top = DRAM_BASE + 0x80000;
        h.set_reg(17, SYS_BRK);
        h.set_reg(10, 0);
        handle_syscall(&mut h, &mut s);
        assert_eq!(h.reg(10), DRAM_BASE + 0x10000);
        h.set_reg(17, SYS_MMAP);
        h.set_reg(10, 0);
        h.set_reg(11, 0x2345);
        handle_syscall(&mut h, &mut s);
        assert_eq!(h.reg(10), DRAM_BASE + 0x80000);
        h.set_reg(17, SYS_MMAP);
        h.set_reg(11, 0x1000);
        handle_syscall(&mut h, &mut s);
        assert_eq!(h.reg(10), DRAM_BASE + 0x80000 + 0x3000);
    }

    #[test]
    fn unknown_syscall_enosys() {
        let (mut h, mut s) = setup();
        h.set_reg(17, 9999);
        handle_syscall(&mut h, &mut s);
        assert_eq!(h.reg(10) as i64, -38);
    }
}
