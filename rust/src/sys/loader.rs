//! Guest image loading: flat `asm::Image`s and minimal ELF64.
//!
//! The ELF loader is dependency-free (parses only what full-system boot
//! needs: PT_LOAD segments + entry point) so externally-built RISC-V
//! binaries can be run when a toolchain is available.

use super::System;
use crate::asm::Image;

/// Load a flat assembled image; returns the entry point.
pub fn load_flat(sys: &System, image: &Image) -> u64 {
    sys.phys.load_image(image.base, &image.bytes);
    image.entry
}

#[derive(Debug)]
pub enum ElfError {
    BadMagic,
    Not64Bit,
    NotRiscV,
    NotExecutable,
    Truncated,
    SegmentOutOfRange { vaddr: u64, size: u64 },
}

impl std::fmt::Display for ElfError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ElfError::BadMagic => write!(f, "not an ELF file"),
            ElfError::Not64Bit => write!(f, "not a 64-bit ELF"),
            ElfError::NotRiscV => write!(f, "not a RISC-V ELF (e_machine != 243)"),
            ElfError::NotExecutable => write!(f, "not ET_EXEC/ET_DYN"),
            ElfError::Truncated => write!(f, "truncated ELF"),
            ElfError::SegmentOutOfRange { vaddr, size } => {
                write!(f, "segment [{:#x}, +{:#x}) outside guest DRAM", vaddr, size)
            }
        }
    }
}

impl std::error::Error for ElfError {}

fn rd16(b: &[u8], off: usize) -> Result<u16, ElfError> {
    b.get(off..off + 2)
        .map(|s| u16::from_le_bytes(s.try_into().unwrap()))
        .ok_or(ElfError::Truncated)
}

fn rd32(b: &[u8], off: usize) -> Result<u32, ElfError> {
    b.get(off..off + 4)
        .map(|s| u32::from_le_bytes(s.try_into().unwrap()))
        .ok_or(ElfError::Truncated)
}

fn rd64(b: &[u8], off: usize) -> Result<u64, ElfError> {
    b.get(off..off + 8)
        .map(|s| u64::from_le_bytes(s.try_into().unwrap()))
        .ok_or(ElfError::Truncated)
}

/// Load a statically-linked ELF64 RISC-V executable; returns the entry PC.
pub fn load_elf(sys: &System, bytes: &[u8]) -> Result<u64, ElfError> {
    if bytes.len() < 64 || &bytes[0..4] != b"\x7fELF" {
        return Err(ElfError::BadMagic);
    }
    if bytes[4] != 2 {
        return Err(ElfError::Not64Bit);
    }
    let e_type = rd16(bytes, 16)?;
    if e_type != 2 && e_type != 3 {
        return Err(ElfError::NotExecutable);
    }
    if rd16(bytes, 18)? != 243 {
        return Err(ElfError::NotRiscV);
    }
    let e_entry = rd64(bytes, 24)?;
    let e_phoff = rd64(bytes, 32)? as usize;
    let e_phentsize = rd16(bytes, 54)? as usize;
    let e_phnum = rd16(bytes, 56)? as usize;

    for i in 0..e_phnum {
        let ph = e_phoff + i * e_phentsize;
        let p_type = rd32(bytes, ph)?;
        if p_type != 1 {
            continue; // PT_LOAD only
        }
        let p_offset = rd64(bytes, ph + 8)? as usize;
        let p_paddr = rd64(bytes, ph + 24)?; // physical address
        let p_filesz = rd64(bytes, ph + 32)? as usize;
        let p_memsz = rd64(bytes, ph + 40)?;
        if !sys.phys.contains(p_paddr, p_memsz) {
            return Err(ElfError::SegmentOutOfRange { vaddr: p_paddr, size: p_memsz });
        }
        let data = bytes.get(p_offset..p_offset + p_filesz).ok_or(ElfError::Truncated)?;
        sys.phys.load_image(p_paddr, data);
        // BSS (memsz > filesz) is already zero (fresh DRAM) — but clear
        // anyway in case of reuse.
        for k in p_filesz as u64..p_memsz {
            sys.phys.write_u8(p_paddr + k, 0);
        }
    }
    Ok(e_entry)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::DRAM_BASE;

    /// Hand-build a minimal ELF with one PT_LOAD segment.
    fn mini_elf(entry: u64, seg_addr: u64, payload: &[u8]) -> Vec<u8> {
        let mut e = vec![0u8; 64 + 56];
        e[0..4].copy_from_slice(b"\x7fELF");
        e[4] = 2; // 64-bit
        e[5] = 1; // little-endian
        e[16..18].copy_from_slice(&2u16.to_le_bytes()); // ET_EXEC
        e[18..20].copy_from_slice(&243u16.to_le_bytes()); // EM_RISCV
        e[24..32].copy_from_slice(&entry.to_le_bytes());
        e[32..40].copy_from_slice(&64u64.to_le_bytes()); // phoff
        e[54..56].copy_from_slice(&56u16.to_le_bytes()); // phentsize
        e[56..58].copy_from_slice(&1u16.to_le_bytes()); // phnum
        // program header at 64
        let ph = 64;
        e[ph..ph + 4].copy_from_slice(&1u32.to_le_bytes()); // PT_LOAD
        let data_off = e.len() as u64;
        e[ph + 8..ph + 16].copy_from_slice(&data_off.to_le_bytes());
        e[ph + 16..ph + 24].copy_from_slice(&seg_addr.to_le_bytes()); // vaddr
        e[ph + 24..ph + 32].copy_from_slice(&seg_addr.to_le_bytes()); // paddr
        e[ph + 32..ph + 40].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        e[ph + 40..ph + 48].copy_from_slice(&(payload.len() as u64 + 16).to_le_bytes()); // memsz > filesz
        e.extend_from_slice(payload);
        e
    }

    #[test]
    fn load_mini_elf() {
        let sys = System::new(1, 1 << 20);
        let elf = mini_elf(DRAM_BASE, DRAM_BASE, &[1, 2, 3, 4]);
        let entry = load_elf(&sys, &elf).unwrap();
        assert_eq!(entry, DRAM_BASE);
        assert_eq!(sys.phys.read_bytes(DRAM_BASE, 4), vec![1, 2, 3, 4]);
    }

    #[test]
    fn reject_non_elf() {
        let sys = System::new(1, 1 << 20);
        assert!(matches!(load_elf(&sys, b"not an elf"), Err(ElfError::BadMagic)));
    }

    #[test]
    fn reject_wrong_machine() {
        let sys = System::new(1, 1 << 20);
        let mut elf = mini_elf(DRAM_BASE, DRAM_BASE, &[0]);
        elf[18..20].copy_from_slice(&62u16.to_le_bytes()); // x86-64
        assert!(matches!(load_elf(&sys, &elf), Err(ElfError::NotRiscV)));
    }

    #[test]
    fn reject_out_of_range_segment() {
        let sys = System::new(1, 1 << 20);
        let elf = mini_elf(0, 0x1000, &[0]); // below DRAM
        assert!(matches!(load_elf(&sys, &elf), Err(ElfError::SegmentOutOfRange { .. })));
    }

    #[test]
    fn load_flat_image() {
        let sys = System::new(1, 1 << 20);
        let mut a = crate::asm::Assembler::new(DRAM_BASE);
        a.nop();
        let img = a.finish();
        assert_eq!(load_flat(&sys, &img), DRAM_BASE);
    }
}
