//! Per-hart architectural state: registers, CSR file, privilege, traps,
//! and the fiber bookkeeping used by the lockstep engine.

use crate::isa::csr::*;

/// A synchronous exception (or, with [`CAUSE_INTERRUPT`] set, an interrupt)
/// to be delivered to the hart.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Trap {
    pub cause: u64,
    pub tval: u64,
}

impl Trap {
    pub fn new(cause: u64, tval: u64) -> Trap {
        Trap { cause, tval }
    }
}

/// Side effects of system instructions that the execution engine (not the
/// hart itself) must act on: code-cache and L0 flushes, model switches.
#[derive(Debug, Default, Clone, Copy)]
pub struct SideEffects {
    /// fence.i executed — flush this hart's code cache.
    pub fence_i: bool,
    /// sfence.vma or satp write — flush translation-derived state
    /// (L0 caches, simulated TLBs, code cache).
    pub sfence: bool,
    /// Translation-affecting mstatus bits (SUM/MXR/MPRV/MPP) changed —
    /// flush the L0 caches (they are virtually tagged, not mode-tagged).
    pub flush_l0: bool,
    /// Vendor SIMCTRL CSR written with this value (§3.5 reconfiguration).
    pub simctrl: Option<u64>,
    /// Region-of-interest marker written (SIMMARK CSR).
    pub mark: Option<u64>,
}

impl SideEffects {
    #[inline]
    pub fn any(&self) -> bool {
        self.fence_i || self.sfence || self.flush_l0 || self.simctrl.is_some() || self.mark.is_some()
    }

    pub fn clear(&mut self) {
        *self = SideEffects::default();
    }
}

/// One simulated hardware thread. `Clone` is derived so checkpointing can
/// serialize a snapshot's hart vector without consuming it.
#[derive(Clone)]
pub struct Hart {
    pub id: usize,
    pub regs: [u64; 32],
    pub pc: u64,
    pub prv: Priv,

    // ---- CSR file ----------------------------------------------------------
    pub mstatus: u64,
    pub mie: u64,
    /// Software-settable interrupt-pending bits (SSIP/STIP via SBI and
    /// sip writes); CLINT/PLIC bits are ORed in dynamically.
    pub mip: u64,
    pub medeleg: u64,
    pub mideleg: u64,
    pub mtvec: u64,
    pub mscratch: u64,
    pub mepc: u64,
    pub mcause: u64,
    pub mtval: u64,
    pub mcounteren: u64,
    pub stvec: u64,
    pub sscratch: u64,
    pub sepc: u64,
    pub scause: u64,
    pub stval: u64,
    pub scounteren: u64,
    pub satp: u64,

    /// Retired instruction counter (minstret).
    pub instret: u64,

    // ---- fiber / timing state ------------------------------------------------
    /// Local cycle clock (mcycle). Advanced at yields.
    pub cycle: u64,
    /// Cycles accumulated since the last yield (§3.3.2 batched yield).
    pub pending: u64,
    /// Waiting for an interrupt (WFI).
    pub wfi: bool,
    /// Hart stopped (simulation exit).
    pub halted: bool,

    // ---- execution support -----------------------------------------------------
    /// Pending side effects for the engine.
    pub effects: SideEffects,
}

impl Hart {
    pub fn new(id: usize) -> Hart {
        Hart {
            id,
            regs: [0; 32],
            pc: 0,
            prv: Priv::Machine,
            mstatus: 0,
            mie: 0,
            mip: 0,
            medeleg: 0,
            mideleg: 0,
            mtvec: 0,
            mscratch: 0,
            mepc: 0,
            mcause: 0,
            mtval: 0,
            mcounteren: 0,
            stvec: 0,
            sscratch: 0,
            sepc: 0,
            scause: 0,
            stval: 0,
            scounteren: 0,
            satp: 0,
            instret: 0,
            cycle: 0,
            pending: 0,
            wfi: false,
            halted: false,
            effects: SideEffects::default(),
        }
    }

    #[inline(always)]
    pub fn reg(&self, r: u8) -> u64 {
        self.regs[r as usize]
    }

    #[inline(always)]
    pub fn set_reg(&mut self, r: u8, v: u64) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    /// Current cycle including not-yet-yielded pending cycles.
    #[inline(always)]
    pub fn now(&self) -> u64 {
        self.cycle + self.pending
    }

    /// MMU context for data accesses (honours MPRV) — see `mem::mmu`.
    pub fn mmu_data_ctx(&self) -> crate::mem::MmuCtx {
        // MPRV: loads/stores execute at MPP privilege when set.
        let prv = if self.mstatus & (1 << 17) != 0 && self.prv == Priv::Machine {
            Priv::from_bits((self.mstatus & MSTATUS_MPP_MASK) >> MSTATUS_MPP_SHIFT)
        } else {
            self.prv
        };
        crate::mem::MmuCtx {
            satp: self.satp,
            prv,
            sum: self.mstatus & MSTATUS_SUM != 0,
            mxr: self.mstatus & MSTATUS_MXR != 0,
        }
    }

    /// MMU context for instruction fetches (MPRV does not apply).
    pub fn mmu_fetch_ctx(&self) -> crate::mem::MmuCtx {
        crate::mem::MmuCtx { satp: self.satp, prv: self.prv, sum: false, mxr: false }
    }

    // ---- CSR access -----------------------------------------------------------

    /// Read a CSR. `time` is the platform time value (CLINT mtime).
    pub fn csr_read(&self, csr: u16, time: u64) -> Result<u64, Trap> {
        self.csr_check(csr, false)?;
        let v = match csr {
            CSR_CYCLE | CSR_MCYCLE => self.now(),
            CSR_TIME => time,
            CSR_INSTRET | CSR_MINSTRET => self.instret,
            CSR_SSTATUS => self.mstatus & (SSTATUS_MASK | MSTATUS_SPIE | MSTATUS_SPP),
            CSR_SIE => self.mie & self.mideleg,
            CSR_STVEC => self.stvec,
            CSR_SCOUNTEREN => self.scounteren,
            CSR_SSCRATCH => self.sscratch,
            CSR_SEPC => self.sepc,
            CSR_SCAUSE => self.scause,
            CSR_STVAL => self.stval,
            CSR_SIP => self.mip & self.mideleg,
            CSR_SATP => self.satp,
            CSR_MVENDORID => 0,
            CSR_MARCHID => 0x52_32_56_4d, // "R2VM"
            CSR_MIMPID => 1,
            CSR_MHARTID => self.id as u64,
            CSR_MSTATUS => self.mstatus,
            CSR_MISA => {
                // RV64IMAC
                (2u64 << 62) | (1 << 0) | (1 << 2) | (1 << 8) | (1 << 12)
            }
            CSR_MEDELEG => self.medeleg,
            CSR_MIDELEG => self.mideleg,
            CSR_MIE => self.mie,
            CSR_MTVEC => self.mtvec,
            CSR_MCOUNTEREN => self.mcounteren,
            CSR_MSCRATCH => self.mscratch,
            CSR_MEPC => self.mepc,
            CSR_MCAUSE => self.mcause,
            CSR_MTVAL => self.mtval,
            CSR_MIP => self.mip,
            // SIMCTRL family reads are handled by the engine (they reflect
            // coordinator state); the hart returns 0 as a placeholder and
            // the engine patches the destination register.
            CSR_SIMCTRL | CSR_SIMSTATS | CSR_SIMMARK => 0,
            _ => return Err(Trap::new(EXC_ILLEGAL, 0)),
        };
        Ok(v)
    }

    /// Write a CSR (side effects recorded in `self.effects`).
    pub fn csr_write(&mut self, csr: u16, value: u64) -> Result<(), Trap> {
        self.csr_check(csr, true)?;
        match csr {
            CSR_SSTATUS => {
                let old = self.mstatus;
                self.mstatus = (self.mstatus & !SSTATUS_MASK) | (value & SSTATUS_MASK);
                if (old ^ self.mstatus) & (MSTATUS_SUM | MSTATUS_MXR) != 0 {
                    self.effects.flush_l0 = true;
                }
            }
            CSR_SIE => {
                self.mie = (self.mie & !self.mideleg) | (value & self.mideleg);
            }
            CSR_STVEC => self.stvec = value & !2,
            CSR_SCOUNTEREN => self.scounteren = value & 0x7,
            CSR_SSCRATCH => self.sscratch = value,
            CSR_SEPC => self.sepc = value & !1,
            CSR_SCAUSE => self.scause = value,
            CSR_STVAL => self.stval = value,
            CSR_SIP => {
                // Only SSIP is software-writable through sip.
                let mask = IRQ_SSIP & self.mideleg;
                self.mip = (self.mip & !mask) | (value & mask);
            }
            CSR_SATP => {
                let mode = value >> 60;
                if mode == 0 || mode == 8 {
                    self.satp = value;
                    self.effects.sfence = true;
                }
                // Other modes: write ignored (WARL).
            }
            CSR_MSTATUS => {
                let mask = MSTATUS_SIE
                    | MSTATUS_MIE
                    | MSTATUS_SPIE
                    | MSTATUS_MPIE
                    | MSTATUS_SPP
                    | MSTATUS_MPP_MASK
                    | MSTATUS_SUM
                    | MSTATUS_MXR
                    | (1 << 17); // MPRV
                let old = self.mstatus;
                self.mstatus = (self.mstatus & !mask) | (value & mask);
                if (old ^ self.mstatus)
                    & (MSTATUS_SUM | MSTATUS_MXR | (1 << 17) | MSTATUS_MPP_MASK)
                    != 0
                {
                    self.effects.flush_l0 = true;
                }
            }
            CSR_MISA => {}
            CSR_MEDELEG => self.medeleg = value & 0xb3ff,
            CSR_MIDELEG => self.mideleg = value & (IRQ_SSIP | IRQ_STIP | IRQ_SEIP),
            CSR_MIE => {
                self.mie = value & (IRQ_SSIP | IRQ_MSIP | IRQ_STIP | IRQ_MTIP | IRQ_SEIP | IRQ_MEIP)
            }
            CSR_MTVEC => self.mtvec = value & !2,
            CSR_MCOUNTEREN => self.mcounteren = value & 0x7,
            CSR_MSCRATCH => self.mscratch = value,
            CSR_MEPC => self.mepc = value & !1,
            CSR_MCAUSE => self.mcause = value,
            CSR_MTVAL => self.mtval = value,
            CSR_MIP => {
                let mask = IRQ_SSIP | IRQ_STIP;
                self.mip = (self.mip & !mask) | (value & mask);
            }
            CSR_MCYCLE => self.cycle = value,
            CSR_MINSTRET => self.instret = value,
            CSR_SIMCTRL => self.effects.simctrl = Some(value),
            CSR_SIMMARK => self.effects.mark = Some(value),
            CSR_SIMSTATS => {}
            _ => return Err(Trap::new(EXC_ILLEGAL, 0)),
        }
        Ok(())
    }

    fn csr_check(&self, csr: u16, write: bool) -> Result<(), Trap> {
        if write && csr_is_readonly(csr) {
            return Err(Trap::new(EXC_ILLEGAL, 0));
        }
        // The SIMCTRL family is deliberately accessible from any privilege
        // so workloads can bracket regions of interest (see isa::csr).
        if matches!(csr, CSR_SIMCTRL | CSR_SIMSTATS | CSR_SIMMARK) {
            return Ok(());
        }
        if self.prv < csr_min_priv(csr) {
            return Err(Trap::new(EXC_ILLEGAL, 0));
        }
        Ok(())
    }

    // ---- traps -------------------------------------------------------------------

    /// Deliver a trap; returns the new PC. `pc` is the PC of the faulting /
    /// interrupted instruction.
    pub fn take_trap(&mut self, trap: Trap, pc: u64) -> u64 {
        let is_interrupt = trap.cause & CAUSE_INTERRUPT != 0;
        let code = trap.cause & !CAUSE_INTERRUPT;
        let delegated = self.prv <= Priv::Supervisor
            && if is_interrupt {
                self.mideleg >> code & 1 != 0
            } else {
                self.medeleg >> code & 1 != 0
            };
        if delegated {
            self.scause = trap.cause;
            self.sepc = pc;
            self.stval = trap.tval;
            // sstatus.SPIE = sstatus.SIE; SIE = 0; SPP = prv
            let sie = (self.mstatus & MSTATUS_SIE) != 0;
            self.mstatus &= !(MSTATUS_SPIE | MSTATUS_SPP | MSTATUS_SIE);
            if sie {
                self.mstatus |= MSTATUS_SPIE;
            }
            if self.prv == Priv::Supervisor {
                self.mstatus |= MSTATUS_SPP;
            }
            self.prv = Priv::Supervisor;
            let base = self.stvec & !3;
            if self.stvec & 1 != 0 && is_interrupt {
                base + 4 * code
            } else {
                base
            }
        } else {
            self.mcause = trap.cause;
            self.mepc = pc;
            self.mtval = trap.tval;
            let mie = (self.mstatus & MSTATUS_MIE) != 0;
            self.mstatus &= !(MSTATUS_MPIE | MSTATUS_MPP_MASK | MSTATUS_MIE);
            if mie {
                self.mstatus |= MSTATUS_MPIE;
            }
            self.mstatus |= (self.prv as u64) << MSTATUS_MPP_SHIFT;
            self.prv = Priv::Machine;
            let base = self.mtvec & !3;
            if self.mtvec & 1 != 0 && is_interrupt {
                base + 4 * code
            } else {
                base
            }
        }
    }

    /// Execute MRET; returns the new PC.
    pub fn mret(&mut self) -> u64 {
        let mpp = Priv::from_bits((self.mstatus & MSTATUS_MPP_MASK) >> MSTATUS_MPP_SHIFT);
        let mpie = self.mstatus & MSTATUS_MPIE != 0;
        self.mstatus &= !(MSTATUS_MIE | MSTATUS_MPIE | MSTATUS_MPP_MASK);
        if mpie {
            self.mstatus |= MSTATUS_MIE;
        }
        self.mstatus |= MSTATUS_MPIE;
        if mpp != Priv::Machine {
            self.mstatus &= !(1 << 17); // clear MPRV on return to < M
        }
        self.prv = mpp;
        self.mepc
    }

    /// Execute SRET; returns the new PC.
    pub fn sret(&mut self) -> u64 {
        let spp =
            if self.mstatus & MSTATUS_SPP != 0 { Priv::Supervisor } else { Priv::User };
        let spie = self.mstatus & MSTATUS_SPIE != 0;
        self.mstatus &= !(MSTATUS_SIE | MSTATUS_SPIE | MSTATUS_SPP);
        if spie {
            self.mstatus |= MSTATUS_SIE;
        }
        self.mstatus |= MSTATUS_SPIE;
        self.prv = spp;
        self.sepc
    }

    /// Highest-priority pending+enabled interrupt, if one should be taken.
    /// `mip_external` is the dynamically-computed CLINT/PLIC contribution.
    pub fn pending_interrupt(&self, mip_external: u64) -> Option<u64> {
        let pending = (self.mip | mip_external) & self.mie;
        if pending == 0 {
            return None;
        }
        // Machine-level interrupts (not delegated).
        let m_pending = pending & !self.mideleg;
        let m_enabled = self.prv < Priv::Machine
            || (self.prv == Priv::Machine && self.mstatus & MSTATUS_MIE != 0);
        if m_pending != 0 && m_enabled {
            // Priority: MEI > MSI > MTI > SEI > SSI > STI
            for bit in [IRQ_MEIP, IRQ_MSIP, IRQ_MTIP, IRQ_SEIP, IRQ_SSIP, IRQ_STIP] {
                if m_pending & bit != 0 {
                    return Some(CAUSE_INTERRUPT | bit.trailing_zeros() as u64);
                }
            }
        }
        // Supervisor-level (delegated) interrupts.
        let s_pending = pending & self.mideleg;
        let s_enabled = self.prv < Priv::Supervisor
            || (self.prv == Priv::Supervisor && self.mstatus & MSTATUS_SIE != 0);
        if s_pending != 0 && s_enabled {
            for bit in [IRQ_SEIP, IRQ_SSIP, IRQ_STIP] {
                if s_pending & bit != 0 {
                    return Some(CAUSE_INTERRUPT | bit.trailing_zeros() as u64);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reg_x0_hardwired() {
        let mut h = Hart::new(0);
        h.set_reg(0, 42);
        assert_eq!(h.reg(0), 0);
        h.set_reg(5, 42);
        assert_eq!(h.reg(5), 42);
    }

    #[test]
    fn csr_privilege_enforced() {
        let mut h = Hart::new(0);
        h.prv = Priv::User;
        assert!(h.csr_read(CSR_MSTATUS, 0).is_err());
        assert!(h.csr_write(CSR_MSTATUS, 0).is_err());
        // counters readable from U (we don't model mcounteren gating of U)
        assert!(h.csr_read(CSR_CYCLE, 0).is_ok());
        // SIMCTRL family exempt
        assert!(h.csr_write(CSR_SIMCTRL, 3).is_ok());
        assert_eq!(h.effects.simctrl, Some(3));
    }

    #[test]
    fn readonly_csr_write_traps() {
        let mut h = Hart::new(0);
        assert!(h.csr_write(CSR_MHARTID, 1).is_err());
        assert_eq!(h.csr_read(CSR_MHARTID, 0).unwrap(), 0);
    }

    #[test]
    fn trap_to_machine_mode() {
        let mut h = Hart::new(0);
        h.prv = Priv::User;
        h.mtvec = 0x8000_0100;
        h.mstatus |= MSTATUS_MIE;
        let target = h.take_trap(Trap::new(EXC_ILLEGAL, 0xbad), 0x8000_0040);
        assert_eq!(target, 0x8000_0100);
        assert_eq!(h.prv, Priv::Machine);
        assert_eq!(h.mepc, 0x8000_0040);
        assert_eq!(h.mcause, EXC_ILLEGAL);
        assert_eq!(h.mtval, 0xbad);
        assert!(h.mstatus & MSTATUS_MIE == 0);
        assert!(h.mstatus & MSTATUS_MPIE != 0);
        // MPP = User
        assert_eq!((h.mstatus & MSTATUS_MPP_MASK) >> MSTATUS_MPP_SHIFT, 0);
    }

    #[test]
    fn trap_delegation_to_supervisor() {
        let mut h = Hart::new(0);
        h.prv = Priv::User;
        h.medeleg = 1 << EXC_ECALL_U;
        h.stvec = 0x8000_0200;
        let target = h.take_trap(Trap::new(EXC_ECALL_U, 0), 0x1000);
        assert_eq!(target, 0x8000_0200);
        assert_eq!(h.prv, Priv::Supervisor);
        assert_eq!(h.sepc, 0x1000);
        // From machine mode, delegation must NOT apply.
        let mut h = Hart::new(0);
        h.prv = Priv::Machine;
        h.medeleg = 1 << EXC_ILLEGAL;
        h.mtvec = 0x8000_0300;
        let target = h.take_trap(Trap::new(EXC_ILLEGAL, 0), 0x1000);
        assert_eq!(target, 0x8000_0300);
        assert_eq!(h.prv, Priv::Machine);
    }

    #[test]
    fn mret_restores() {
        let mut h = Hart::new(0);
        h.prv = Priv::User;
        h.mtvec = 0x100;
        h.take_trap(Trap::new(EXC_ECALL_U, 0), 0x4000);
        assert_eq!(h.prv, Priv::Machine);
        let pc = h.mret();
        assert_eq!(pc, 0x4000);
        assert_eq!(h.prv, Priv::User);
    }

    #[test]
    fn sret_restores() {
        let mut h = Hart::new(0);
        h.prv = Priv::User;
        h.mideleg = IRQ_SSIP;
        h.medeleg = 1 << EXC_ECALL_U;
        h.stvec = 0x200;
        h.take_trap(Trap::new(EXC_ECALL_U, 0), 0x5000);
        assert_eq!(h.prv, Priv::Supervisor);
        let pc = h.sret();
        assert_eq!(pc, 0x5000);
        assert_eq!(h.prv, Priv::User);
    }

    #[test]
    fn interrupt_priority_and_enables() {
        let mut h = Hart::new(0);
        h.prv = Priv::Machine;
        h.mie = IRQ_MTIP | IRQ_MSIP;
        // MIE off in M-mode: no interrupt.
        assert_eq!(h.pending_interrupt(IRQ_MTIP), None);
        h.mstatus |= MSTATUS_MIE;
        assert_eq!(h.pending_interrupt(IRQ_MTIP), Some(CAUSE_INTERRUPT | 7));
        // MSI beats MTI.
        assert_eq!(h.pending_interrupt(IRQ_MTIP | IRQ_MSIP), Some(CAUSE_INTERRUPT | 3));
        // Lower privilege always takes machine interrupts.
        h.prv = Priv::User;
        h.mstatus &= !MSTATUS_MIE;
        assert_eq!(h.pending_interrupt(IRQ_MTIP), Some(CAUSE_INTERRUPT | 7));
    }

    #[test]
    fn delegated_interrupt_in_smode() {
        let mut h = Hart::new(0);
        h.prv = Priv::Supervisor;
        h.mideleg = IRQ_SSIP;
        h.mie = IRQ_SSIP;
        h.mip = IRQ_SSIP;
        assert_eq!(h.pending_interrupt(0), None); // SIE off
        h.mstatus |= MSTATUS_SIE;
        assert_eq!(h.pending_interrupt(0), Some(CAUSE_INTERRUPT | 1));
        // In M-mode, delegated interrupts are masked.
        h.prv = Priv::Machine;
        h.mstatus |= MSTATUS_MIE;
        assert_eq!(h.pending_interrupt(0), None);
    }

    #[test]
    fn sstatus_view() {
        let mut h = Hart::new(0);
        h.csr_write(CSR_MSTATUS, MSTATUS_SIE | MSTATUS_MIE | MSTATUS_SUM).unwrap();
        let s = h.csr_read(CSR_SSTATUS, 0).unwrap();
        assert!(s & MSTATUS_SIE != 0);
        assert!(s & MSTATUS_SUM != 0);
        assert!(s & MSTATUS_MIE == 0, "machine bits must not leak into sstatus");
    }
}
