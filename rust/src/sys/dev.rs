//! Memory-mapped devices and the MMIO bus.
//!
//! Full-system simulation needs a minimal platform besides DRAM: a CLINT
//! (per-hart software + timer interrupts), a UART for console output, a
//! skeletal PLIC, and `SIMIO` — a simulator-specific exit/console device
//! akin to riscv-tests' `tohost` (used by bare-metal workloads).
//!
//! MMIO is *never* cached in the L0 layer, so every device access takes the
//! memory-model cold path — exactly the behaviour the paper needs for its
//! synchronisation-point argument (§3.3.2: I/O has "inherent entropy").

use crate::isa::csr::{IRQ_MSIP, IRQ_MTIP};

pub const CLINT_BASE: u64 = 0x0200_0000;
pub const CLINT_SIZE: u64 = 0x10000;
pub const UART_BASE: u64 = 0x1000_0000;
pub const UART_SIZE: u64 = 0x100;
pub const PLIC_BASE: u64 = 0x0C00_0000;
pub const PLIC_SIZE: u64 = 0x400_0000;
pub const SIMIO_BASE: u64 = 0x0010_0000;
pub const SIMIO_SIZE: u64 = 0x1000;

/// Fixed MMIO access latency in cycles (charged by timing memory models).
pub const MMIO_LATENCY: u64 = 20;

// ---------------------------------------------------------------------------
// CLINT
// ---------------------------------------------------------------------------

/// Core-local interruptor: per-hart MSIP bits and timer compare registers,
/// plus the global `mtime` counter (driven by the simulation clock).
pub struct Clint {
    pub msip: Vec<bool>,
    pub mtimecmp: Vec<u64>,
    /// Per-hart "mtimecmp was written" latches — the sharded engine's
    /// boundary forwarding consumes these so *every* cross-shard timer
    /// write is forwarded, including rewrites of the current value and
    /// disarms back to `u64::MAX` (a value diff would miss both).
    pub mtimecmp_written: Vec<bool>,
    /// Per-hart "mtimecmp was read" latches. The sharded engine's boundary
    /// forwarding turns a latched read of a *remote* hart's entry into a
    /// mailbox request for the owner's authoritative value, so a guest
    /// polling another hart's timer converges on the real deadline instead
    /// of a stale forwarding snapshot.
    pub mtimecmp_read: Vec<bool>,
    /// Ratio of cycles per mtime tick (1 = mtime counts cycles).
    pub time_shift: u32,
}

impl Clint {
    pub fn new(harts: usize) -> Clint {
        Clint {
            msip: vec![false; harts],
            mtimecmp: vec![u64::MAX; harts],
            mtimecmp_written: vec![false; harts],
            mtimecmp_read: vec![false; harts],
            time_shift: 0,
        }
    }

    #[inline]
    pub fn mtime(&self, now_cycle: u64) -> u64 {
        now_cycle >> self.time_shift
    }

    /// Interrupt bits (MSIP/MTIP) currently pending for `hart`.
    #[inline]
    pub fn mip_bits(&self, hart: usize, now_cycle: u64) -> u64 {
        let mut bits = 0;
        if self.msip[hart] {
            bits |= IRQ_MSIP;
        }
        if self.mtime(now_cycle) >= self.mtimecmp[hart] {
            bits |= IRQ_MTIP;
        }
        bits
    }

    /// Earliest cycle at which a timer interrupt will fire for any hart
    /// (used by the lockstep engine to wake WFI sleepers).
    pub fn next_timer_deadline(&self) -> Option<u64> {
        self.mtimecmp
            .iter()
            .copied()
            .filter(|&t| t != u64::MAX)
            .min()
            .map(|t| t << self.time_shift)
    }

    pub fn read(&mut self, offset: u64, now_cycle: u64) -> u64 {
        match offset {
            // msip registers: 4 bytes per hart
            o if o < 0x4000 => {
                let hart = (o / 4) as usize;
                if o % 4 == 0 && hart < self.msip.len() {
                    self.msip[hart] as u64
                } else {
                    0
                }
            }
            // mtimecmp: 8 bytes per hart at 0x4000
            o if (0x4000..0xBFF8).contains(&o) => {
                let hart = ((o - 0x4000) / 8) as usize;
                if hart < self.mtimecmp.len() {
                    self.mtimecmp_read[hart] = true;
                    let v = self.mtimecmp[hart];
                    if (o - 0x4000) % 8 == 0 {
                        v
                    } else {
                        v >> 32
                    }
                } else {
                    0
                }
            }
            0xBFF8 => self.mtime(now_cycle),
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u64, value: u64, size: u64) {
        match offset {
            o if o < 0x4000 => {
                let hart = (o / 4) as usize;
                if o % 4 == 0 && hart < self.msip.len() {
                    self.msip[hart] = value & 1 != 0;
                }
            }
            o if (0x4000..0xBFF8).contains(&o) => {
                let idx = ((o - 0x4000) / 8) as usize;
                if idx < self.mtimecmp.len() {
                    if size == 8 && (o - 0x4000) % 8 == 0 {
                        self.mtimecmp[idx] = value;
                    } else if (o - 0x4000) % 8 == 0 {
                        // low word
                        self.mtimecmp[idx] = (self.mtimecmp[idx] & !0xffff_ffff) | (value & 0xffff_ffff);
                    } else {
                        // high word
                        self.mtimecmp[idx] =
                            (self.mtimecmp[idx] & 0xffff_ffff) | ((value & 0xffff_ffff) << 32);
                    }
                    self.mtimecmp_written[idx] = true;
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// UART (8250-lite, output only)
// ---------------------------------------------------------------------------

#[derive(Default)]
pub struct Uart {
    /// Captured console output.
    pub output: Vec<u8>,
    /// Echo bytes to host stdout as they arrive.
    pub echo: bool,
}

impl Uart {
    pub fn read(&self, offset: u64) -> u64 {
        match offset {
            // LSR: transmitter empty + THR empty
            5 => 0x60,
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u64, value: u64) {
        if offset == 0 {
            let b = value as u8;
            self.output.push(b);
            if self.echo {
                use std::io::Write;
                let _ = std::io::stdout().write_all(&[b]);
                let _ = std::io::stdout().flush();
            }
        }
    }

    pub fn output_str(&self) -> String {
        String::from_utf8_lossy(&self.output).into_owned()
    }
}

// ---------------------------------------------------------------------------
// PLIC (skeletal)
// ---------------------------------------------------------------------------

/// Minimal PLIC: register storage for priorities/enables/thresholds so
/// guests can probe and program it; no external sources are wired in this
/// environment, so it never asserts MEIP/SEIP.
pub struct Plic {
    pub priority: Vec<u32>,
    pub enable: Vec<u32>,
    pub threshold: Vec<u32>,
}

impl Plic {
    pub fn new(harts: usize) -> Plic {
        Plic {
            priority: vec![0; 32],
            // one enable word + one threshold per context (2 contexts/hart: M and S)
            enable: vec![0; harts * 2],
            threshold: vec![0; harts * 2],
        }
    }

    pub fn read(&self, offset: u64) -> u64 {
        match offset {
            o if o < 0x1000 => {
                let idx = (o / 4) as usize;
                *self.priority.get(idx).unwrap_or(&0) as u64
            }
            o if (0x2000..0x20_0000).contains(&o) => {
                let ctx = ((o - 0x2000) / 0x80) as usize;
                *self.enable.get(ctx).unwrap_or(&0) as u64
            }
            o if o >= 0x20_0000 => {
                let ctx = ((o - 0x20_0000) / 0x1000) as usize;
                if (o - 0x20_0000) % 0x1000 == 0 {
                    *self.threshold.get(ctx).unwrap_or(&0) as u64
                } else {
                    0 // claim: no pending sources
                }
            }
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u64, value: u64) {
        match offset {
            o if o < 0x1000 => {
                let idx = (o / 4) as usize;
                if let Some(p) = self.priority.get_mut(idx) {
                    *p = value as u32;
                }
            }
            o if (0x2000..0x20_0000).contains(&o) => {
                let ctx = ((o - 0x2000) / 0x80) as usize;
                if let Some(e) = self.enable.get_mut(ctx) {
                    *e = value as u32;
                }
            }
            o if o >= 0x20_0000 => {
                let ctx = ((o - 0x20_0000) / 0x1000) as usize;
                if (o - 0x20_0000) % 0x1000 == 0 {
                    if let Some(t) = self.threshold.get_mut(ctx) {
                        *t = value as u32;
                    }
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// SIMIO (simulator control device)
// ---------------------------------------------------------------------------

/// Bare-metal workload interface, riscv-tests `tohost` style:
///   +0  write: terminate simulation, exit code = value >> 1 (if lsb set)
///   +8  write: console putchar
///   +16 write: observability trace window (nonzero = open, 0 = close);
///       MMIO alternative to the SIMCTRL pulse bits for workloads that
///       bracket their region of interest from C instead of CSR asm
pub struct SimIo {
    pub exit_code: Option<u64>,
    pub console: Vec<u8>,
    /// Latched trace-window request; the engine's observability tick
    /// consumes it (`None` when nothing was written since).
    pub trace_req: Option<bool>,
}

impl SimIo {
    pub fn new() -> SimIo {
        SimIo { exit_code: None, console: Vec::new(), trace_req: None }
    }

    pub fn write(&mut self, offset: u64, value: u64) {
        match offset {
            0 => {
                if value & 1 != 0 {
                    self.exit_code = Some(value >> 1);
                }
            }
            8 => self.console.push(value as u8),
            16 => self.trace_req = Some(value != 0),
            _ => {}
        }
    }

    pub fn read(&self, _offset: u64) -> u64 {
        0
    }
}

impl Default for SimIo {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// Bus
// ---------------------------------------------------------------------------

/// All MMIO devices behind one dispatcher.
pub struct DeviceBus {
    pub clint: Clint,
    pub uart: Uart,
    pub plic: Plic,
    pub simio: SimIo,
}

impl DeviceBus {
    pub fn new(harts: usize) -> DeviceBus {
        DeviceBus {
            clint: Clint::new(harts),
            uart: Uart::default(),
            plic: Plic::new(harts),
            simio: SimIo::new(),
        }
    }

    /// Is `paddr` an MMIO address (must bypass L0 and DRAM)?
    #[inline]
    pub fn is_mmio(paddr: u64) -> bool {
        (CLINT_BASE..CLINT_BASE + CLINT_SIZE).contains(&paddr)
            || (UART_BASE..UART_BASE + UART_SIZE).contains(&paddr)
            || (PLIC_BASE..PLIC_BASE + PLIC_SIZE).contains(&paddr)
            || (SIMIO_BASE..SIMIO_BASE + SIMIO_SIZE).contains(&paddr)
    }

    pub fn read(&mut self, paddr: u64, _size: u64, now_cycle: u64) -> u64 {
        match paddr {
            p if (CLINT_BASE..CLINT_BASE + CLINT_SIZE).contains(&p) => {
                self.clint.read(p - CLINT_BASE, now_cycle)
            }
            p if (UART_BASE..UART_BASE + UART_SIZE).contains(&p) => self.uart.read(p - UART_BASE),
            p if (PLIC_BASE..PLIC_BASE + PLIC_SIZE).contains(&p) => self.plic.read(p - PLIC_BASE),
            p if (SIMIO_BASE..SIMIO_BASE + SIMIO_SIZE).contains(&p) => {
                self.simio.read(p - SIMIO_BASE)
            }
            _ => 0,
        }
    }

    pub fn write(&mut self, paddr: u64, value: u64, size: u64) {
        match paddr {
            p if (CLINT_BASE..CLINT_BASE + CLINT_SIZE).contains(&p) => {
                self.clint.write(p - CLINT_BASE, value, size)
            }
            p if (UART_BASE..UART_BASE + UART_SIZE).contains(&p) => {
                self.uart.write(p - UART_BASE, value)
            }
            p if (PLIC_BASE..PLIC_BASE + PLIC_SIZE).contains(&p) => {
                self.plic.write(p - PLIC_BASE, value)
            }
            p if (SIMIO_BASE..SIMIO_BASE + SIMIO_SIZE).contains(&p) => {
                self.simio.write(p - SIMIO_BASE, value)
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clint_msip() {
        let mut c = Clint::new(2);
        c.write(4, 1, 4); // msip[1]
        assert_eq!(c.mip_bits(1, 0), IRQ_MSIP);
        assert_eq!(c.mip_bits(0, 0), 0);
        c.write(4, 0, 4);
        assert_eq!(c.mip_bits(1, 0), 0);
    }

    #[test]
    fn clint_timer() {
        let mut c = Clint::new(1);
        c.write(0x4000, 1000, 8);
        assert_eq!(c.mip_bits(0, 999), 0);
        assert_eq!(c.mip_bits(0, 1000), IRQ_MTIP);
        assert_eq!(c.read(0xBFF8, 1234), 1234);
        assert_eq!(c.next_timer_deadline(), Some(1000));
    }

    #[test]
    fn clint_mtimecmp_split_words() {
        let mut c = Clint::new(1);
        c.write(0x4000, 0xdead_beef, 4);
        c.write(0x4004, 0x1234, 4);
        assert_eq!(c.mtimecmp[0], 0x1234_dead_beef);
    }

    #[test]
    fn clint_mtimecmp_write_latch() {
        // The sharded boundary forwarding keys off the write latch, so
        // value-preserving writes (a disarm of an already-MAX entry, a
        // rewrite of the current deadline) must still set it.
        let mut c = Clint::new(2);
        assert!(!c.mtimecmp_written[1]);
        c.write(0x4008, u64::MAX, 8); // disarm == current value
        assert!(c.mtimecmp_written[1], "rewrite of the current value must latch");
        assert!(!c.mtimecmp_written[0]);
        c.mtimecmp_written[1] = false;
        c.write(0x4008, 500, 8);
        assert!(c.mtimecmp_written[1]);
        // msip writes do not touch the timer latch.
        c.mtimecmp_written[1] = false;
        c.write(4, 1, 4);
        assert!(!c.mtimecmp_written[1]);
    }

    #[test]
    fn clint_mtimecmp_read_latch() {
        // The sharded boundary forwarding turns latched remote reads into
        // value requests, so any mtimecmp read — full or split word — must
        // latch, and nothing else (msip, mtime) may.
        let mut c = Clint::new(2);
        c.read(0x4008, 0);
        assert!(c.mtimecmp_read[1] && !c.mtimecmp_read[0]);
        c.mtimecmp_read[1] = false;
        c.read(0x400c, 0); // high word of mtimecmp[1]
        assert!(c.mtimecmp_read[1]);
        c.mtimecmp_read[1] = false;
        c.read(0, 0); // msip
        c.read(0xBFF8, 0); // mtime
        assert!(!c.mtimecmp_read[0] && !c.mtimecmp_read[1]);
    }

    #[test]
    fn uart_output() {
        let mut u = Uart::default();
        for b in b"hi" {
            u.write(0, *b as u64);
        }
        assert_eq!(u.output_str(), "hi");
        assert_eq!(u.read(5), 0x60);
    }

    #[test]
    fn simio_exit() {
        let mut s = SimIo::new();
        s.write(0, (42 << 1) | 1);
        assert_eq!(s.exit_code, Some(42));
    }

    #[test]
    fn simio_trace_window_latch() {
        let mut s = SimIo::new();
        assert_eq!(s.trace_req, None);
        s.write(16, 0);
        assert_eq!(s.trace_req, Some(false), "zero closes the window");
        s.write(16, 1);
        assert_eq!(s.trace_req, Some(true), "last write wins until consumed");
        assert_eq!(s.trace_req.take(), Some(true), "engine tick consumes the latch");
        assert_eq!(s.trace_req, None);
        // Exit/console writes do not disturb the latch.
        s.write(8, b'x' as u64);
        assert_eq!(s.trace_req, None);
    }

    #[test]
    fn bus_dispatch() {
        let mut bus = DeviceBus::new(1);
        assert!(DeviceBus::is_mmio(UART_BASE));
        assert!(DeviceBus::is_mmio(CLINT_BASE + 0x4000));
        assert!(!DeviceBus::is_mmio(0x8000_0000));
        bus.write(UART_BASE, b'x' as u64, 1);
        assert_eq!(bus.uart.output, vec![b'x']);
        bus.write(CLINT_BASE, 1, 4);
        assert_eq!(bus.clint.mip_bits(0, 0), IRQ_MSIP);
    }
}
