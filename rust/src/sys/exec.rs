//! Instruction execution semantics.
//!
//! `exec_op` is the single source of semantic truth: both the naive
//! per-cycle interpreter (the gem5-like baseline) and the DBT engine's
//! translated micro-op traces execute through it, so timing modes can never
//! diverge functionally from the baseline.
//!
//! Memory accesses implement the paper's two-level scheme: the L0 fast path
//! (3 host memory operations, §3.4.1) and the memory-model cold path
//! (translate → simulate → install).

use super::dev::{DeviceBus, MMIO_LATENCY};
use super::hart::{Hart, Trap};
use super::System;
use crate::isa::csr::*;
use crate::isa::op::*;
use crate::mem::mmu::{translate, AccessKind, PageFault};

/// Control-flow outcome of executing one instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flow {
    /// Fall through to the next sequential instruction.
    Next,
    /// Conditional branch: taken (target = pc + imm, computed by caller).
    Taken,
    /// Unconditional transfer to an absolute address.
    Jump(u64),
    /// WFI executed; sleep until an interrupt is pending.
    Wfi,
}

#[inline]
fn page_fault_trap(pf: PageFault, vaddr: u64) -> Trap {
    let cause = match pf.kind {
        AccessKind::Read => EXC_LOAD_PAGE_FAULT,
        AccessKind::Write => EXC_STORE_PAGE_FAULT,
        AccessKind::Execute => EXC_INSN_PAGE_FAULT,
    };
    Trap::new(cause, vaddr)
}

// ---------------------------------------------------------------------------
// Memory access
// ---------------------------------------------------------------------------

/// Raw physical read of `width` bytes (zero-extended).
#[inline(always)]
fn phys_read(sys: &System, paddr: u64, width: MemWidth) -> u64 {
    match width {
        MemWidth::B => sys.phys.read_u8(paddr) as u64,
        MemWidth::H => sys.phys.read_u16(paddr) as u64,
        MemWidth::W => sys.phys.read_u32(paddr) as u64,
        MemWidth::D => sys.phys.read_u64(paddr),
    }
}

#[inline(always)]
fn phys_write(sys: &System, paddr: u64, width: MemWidth, value: u64) {
    match width {
        MemWidth::B => sys.phys.write_u8(paddr, value as u8),
        MemWidth::H => sys.phys.write_u16(paddr, value as u16),
        MemWidth::W => sys.phys.write_u32(paddr, value as u32),
        MemWidth::D => sys.phys.write_u64(paddr, value),
    }
}

/// Cold path for data accesses: translate, run the memory model, install
/// the line into L0 per the model's decision, charge cycles. Returns the
/// physical address.
#[cold]
fn cold_data_access(
    hart: &mut Hart,
    sys: &mut System,
    vaddr: u64,
    write: bool,
) -> Result<u64, Trap> {
    let ctx = hart.mmu_data_ctx();
    let kind = if write { AccessKind::Write } else { AccessKind::Read };
    let tr = translate(&sys.phys, &ctx, vaddr, kind).map_err(|pf| page_fault_trap(pf, vaddr))?;

    // MMIO bypasses the L0 and the memory model entirely (§3.3.2: device
    // accesses are synchronisation points with fixed latency).
    if DeviceBus::is_mmio(tr.paddr) {
        hart.pending += MMIO_LATENCY;
        return Ok(tr.paddr);
    }
    if !sys.phys.contains(tr.paddr, 8) {
        let cause = if write { EXC_STORE_ACCESS } else { EXC_LOAD_ACCESS };
        return Err(Trap::new(cause, vaddr));
    }

    let cold = sys.model.data_access(&mut sys.l0, hart.id, vaddr, &tr, write);
    hart.pending += cold.cycles;
    if let Some(writable) = cold.install {
        // A write may only install a writable entry; a read may install
        // read-only (so stores still reach the cold path).
        sys.l0[hart.id].d.insert(vaddr, tr.paddr, writable);
    }
    Ok(tr.paddr)
}

/// Cold continuation of a load after an L0 miss (also the entire path
/// under `force_cold`): translate + memory model, MMIO, trace. Keeping
/// this out of line leaves [`read_mem`]'s inlined body as just the
/// alignment check + the 3-host-op L0 hit (§3.4.1) wherever it lands —
/// including the DBT step loop's load fast path.
#[cold]
fn read_mem_miss(
    hart: &mut Hart,
    sys: &mut System,
    vaddr: u64,
    width: MemWidth,
) -> Result<u64, Trap> {
    let paddr = cold_data_access(hart, sys, vaddr, false)?;
    if DeviceBus::is_mmio(paddr) {
        let now = hart.now();
        return Ok(sys.bus.read(paddr, width.bytes(), now));
    }
    if let Some(t) = sys.trace.as_mut() {
        t.record_mem(paddr, false, hart.id as u8);
    }
    Ok(phys_read(sys, paddr, width))
}

/// Load `width` bytes at `vaddr` (unsigned). The L0 fast path is inlined;
/// misses go through the memory model. An L0 hit costs the paper's 3 host
/// memory operations (tag compare, XOR, data read) — hits never cover
/// MMIO, so no device check is needed on the hot path.
#[inline(always)]
pub fn read_mem(hart: &mut Hart, sys: &mut System, vaddr: u64, width: MemWidth) -> Result<u64, Trap> {
    // Line-crossing misaligned accesses trap (RISC-V permits this; guest
    // workloads are compiled aligned).
    let line_mask = (1u64 << sys.l0[hart.id].d.line_shift()) - 1;
    if (vaddr & line_mask) + width.bytes() > line_mask + 1 {
        return Err(Trap::new(EXC_LOAD_MISALIGNED, vaddr));
    }
    if !sys.force_cold {
        if let Some(paddr) = sys.l0[hart.id].d.lookup_read(vaddr) {
            if let Some(t) = sys.trace.as_mut() {
                t.record_mem(paddr, false, hart.id as u8);
            }
            return Ok(phys_read(sys, paddr, width));
        }
    }
    read_mem_miss(hart, sys, vaddr, width)
}

/// Non-MMIO store commit: reservation clearing, trace, physical write
/// (shared by the hit and miss paths so the protocol cannot drift).
#[inline(always)]
fn commit_store(hart_id: usize, sys: &mut System, paddr: u64, width: MemWidth, value: u64) {
    if sys.active_reservations != 0 {
        sys.clear_reservations(paddr, hart_id);
    }
    if let Some(t) = sys.trace.as_mut() {
        t.record_mem(paddr, true, hart_id as u8);
    }
    phys_write(sys, paddr, width, value);
}

/// Cold continuation of a store after an L0 miss (see [`read_mem_miss`]).
#[cold]
fn write_mem_miss(
    hart: &mut Hart,
    sys: &mut System,
    vaddr: u64,
    width: MemWidth,
    value: u64,
) -> Result<(), Trap> {
    let paddr = cold_data_access(hart, sys, vaddr, true)?;
    if DeviceBus::is_mmio(paddr) {
        sys.bus.write(paddr, value, width.bytes());
        return Ok(());
    }
    commit_store(hart.id, sys, paddr, width, value);
    Ok(())
}

/// Store `width` bytes at `vaddr`.
#[inline(always)]
pub fn write_mem(
    hart: &mut Hart,
    sys: &mut System,
    vaddr: u64,
    width: MemWidth,
    value: u64,
) -> Result<(), Trap> {
    let line_mask = (1u64 << sys.l0[hart.id].d.line_shift()) - 1;
    if (vaddr & line_mask) + width.bytes() > line_mask + 1 {
        return Err(Trap::new(EXC_STORE_MISALIGNED, vaddr));
    }
    if !sys.force_cold {
        if let Some(paddr) = sys.l0[hart.id].d.lookup_write(vaddr) {
            commit_store(hart.id, sys, paddr, width, value);
            return Ok(());
        }
    }
    write_mem_miss(hart, sys, vaddr, width, value)
}

/// Sign- or zero-extend a loaded value (public for the DBT fast path).
#[inline(always)]
pub fn sext_load(value: u64, width: MemWidth, signed: bool) -> u64 {
    if !signed {
        return value;
    }
    match width {
        MemWidth::B => value as u8 as i8 as i64 as u64,
        MemWidth::H => value as u16 as i16 as i64 as u64,
        MemWidth::W => value as u32 as i32 as i64 as u64,
        MemWidth::D => value,
    }
}

// ---------------------------------------------------------------------------
// ALU helpers
// ---------------------------------------------------------------------------

/// Public ALU evaluator — used by the fiber engine's inline fast path.
#[inline(always)]
pub fn alu_value(op: AluOp, word: bool, a: u64, b: u64) -> u64 {
    alu(op, word, a, b)
}

#[inline(always)]
fn alu(op: AluOp, word: bool, a: u64, b: u64) -> u64 {
    if word {
        let a32 = a as u32;
        let b32 = b as u32;
        let r = match op {
            AluOp::Add => a32.wrapping_add(b32),
            AluOp::Sub => a32.wrapping_sub(b32),
            AluOp::Sll => a32.wrapping_shl(b32 & 31),
            AluOp::Srl => a32.wrapping_shr(b32 & 31),
            AluOp::Sra => ((a32 as i32).wrapping_shr(b32 & 31)) as u32,
            // Slt/Sltu/Xor/Or/And have no word forms in the ISA, but be total:
            AluOp::Slt => ((a32 as i32) < (b32 as i32)) as u32,
            AluOp::Sltu => (a32 < b32) as u32,
            AluOp::Xor => a32 ^ b32,
            AluOp::Or => a32 | b32,
            AluOp::And => a32 & b32,
        };
        r as i32 as i64 as u64
    } else {
        match op {
            AluOp::Add => a.wrapping_add(b),
            AluOp::Sub => a.wrapping_sub(b),
            AluOp::Sll => a.wrapping_shl(b as u32 & 63),
            AluOp::Srl => a.wrapping_shr(b as u32 & 63),
            AluOp::Sra => ((a as i64).wrapping_shr(b as u32 & 63)) as u64,
            AluOp::Slt => ((a as i64) < (b as i64)) as u64,
            AluOp::Sltu => (a < b) as u64,
            AluOp::Xor => a ^ b,
            AluOp::Or => a | b,
            AluOp::And => a & b,
        }
    }
}

/// Public M-extension evaluator — the native DBT backend's mul/div
/// helper routes through here so edge cases (division by zero, overflow,
/// mulh) can never diverge from the interpreter.
#[inline(always)]
pub fn mul_value(op: MulOp, word: bool, a: u64, b: u64) -> u64 {
    mul(op, word, a, b)
}

#[inline(always)]
fn mul(op: MulOp, word: bool, a: u64, b: u64) -> u64 {
    if word {
        let a32 = a as i32;
        let b32 = b as i32;
        let r: i32 = match op {
            MulOp::Mul => a32.wrapping_mul(b32),
            MulOp::Div => {
                if b32 == 0 {
                    -1
                } else if a32 == i32::MIN && b32 == -1 {
                    i32::MIN
                } else {
                    a32.wrapping_div(b32)
                }
            }
            MulOp::Divu => {
                if b32 == 0 {
                    -1
                } else {
                    ((a as u32) / (b as u32)) as i32
                }
            }
            MulOp::Rem => {
                if b32 == 0 {
                    a32
                } else if a32 == i32::MIN && b32 == -1 {
                    0
                } else {
                    a32.wrapping_rem(b32)
                }
            }
            MulOp::Remu => {
                if b as u32 == 0 {
                    a as u32 as i32
                } else {
                    ((a as u32) % (b as u32)) as i32
                }
            }
            // Mulh variants have no word form; be total.
            MulOp::Mulh | MulOp::Mulhsu | MulOp::Mulhu => {
                ((a32 as i64).wrapping_mul(b32 as i64) >> 32) as i32
            }
        };
        r as i64 as u64
    } else {
        match op {
            MulOp::Mul => a.wrapping_mul(b),
            MulOp::Mulh => (((a as i64 as i128) * (b as i64 as i128)) >> 64) as u64,
            MulOp::Mulhsu => (((a as i64 as i128) * (b as u128 as i128)) >> 64) as u64,
            MulOp::Mulhu => (((a as u128) * (b as u128)) >> 64) as u64,
            MulOp::Div => {
                let (a, b) = (a as i64, b as i64);
                if b == 0 {
                    u64::MAX
                } else if a == i64::MIN && b == -1 {
                    a as u64
                } else {
                    a.wrapping_div(b) as u64
                }
            }
            MulOp::Divu => {
                if b == 0 {
                    u64::MAX
                } else {
                    a / b
                }
            }
            MulOp::Rem => {
                let (a, b) = (a as i64, b as i64);
                if b == 0 {
                    a as u64
                } else if a == i64::MIN && b == -1 {
                    0
                } else {
                    a.wrapping_rem(b) as u64
                }
            }
            MulOp::Remu => {
                if b == 0 {
                    a
                } else {
                    a % b
                }
            }
        }
    }
}

#[inline(always)]
fn amo_compute(op: AmoOp, width: MemWidth, old: u64, src: u64) -> u64 {
    let r = match op {
        AmoOp::Swap => src,
        AmoOp::Add => {
            if width == MemWidth::W {
                (old as u32).wrapping_add(src as u32) as u64
            } else {
                old.wrapping_add(src)
            }
        }
        AmoOp::Xor => old ^ src,
        AmoOp::And => old & src,
        AmoOp::Or => old | src,
        AmoOp::Min => {
            if width == MemWidth::W {
                ((old as i32).min(src as i32)) as u32 as u64
            } else {
                ((old as i64).min(src as i64)) as u64
            }
        }
        AmoOp::Max => {
            if width == MemWidth::W {
                ((old as i32).max(src as i32)) as u32 as u64
            } else {
                ((old as i64).max(src as i64)) as u64
            }
        }
        AmoOp::Minu => {
            if width == MemWidth::W {
                ((old as u32).min(src as u32)) as u64
            } else {
                old.min(src)
            }
        }
        AmoOp::Maxu => {
            if width == MemWidth::W {
                ((old as u32).max(src as u32)) as u64
            } else {
                old.max(src)
            }
        }
    };
    r
}

// ---------------------------------------------------------------------------
// exec_op
// ---------------------------------------------------------------------------

/// Execute one decoded instruction.
///
/// `pc` is the instruction's address, `npc` the next sequential address
/// (pc + 2 or 4). The caller is responsible for retiring (`instret`) and
/// for PC updates:
/// `Flow::Next` → npc, `Flow::Taken` → pc + branch imm, `Flow::Jump(t)` → t.
pub fn exec_op(
    hart: &mut Hart,
    sys: &mut System,
    op: &Op,
    pc: u64,
    npc: u64,
) -> Result<Flow, Trap> {
    match *op {
        Op::Illegal { raw } => Err(Trap::new(EXC_ILLEGAL, raw as u64)),

        Op::Lui { rd, imm } => {
            hart.set_reg(rd, imm as i64 as u64);
            Ok(Flow::Next)
        }
        Op::Auipc { rd, imm } => {
            hart.set_reg(rd, pc.wrapping_add(imm as i64 as u64));
            Ok(Flow::Next)
        }
        Op::Jal { rd, imm } => {
            hart.set_reg(rd, npc);
            Ok(Flow::Jump(pc.wrapping_add(imm as i64 as u64)))
        }
        Op::Jalr { rd, rs1, imm } => {
            let target = hart.reg(rs1).wrapping_add(imm as i64 as u64) & !1;
            hart.set_reg(rd, npc);
            Ok(Flow::Jump(target))
        }
        Op::Branch { cond, rs1, rs2, .. } => {
            if cond.eval(hart.reg(rs1), hart.reg(rs2)) {
                Ok(Flow::Taken)
            } else {
                Ok(Flow::Next)
            }
        }

        Op::Load { width, signed, rd, rs1, imm } => {
            let vaddr = hart.reg(rs1).wrapping_add(imm as i64 as u64);
            let raw = read_mem(hart, sys, vaddr, width)?;
            hart.set_reg(rd, sext_load(raw, width, signed));
            Ok(Flow::Next)
        }
        Op::Store { width, rs1, rs2, imm } => {
            let vaddr = hart.reg(rs1).wrapping_add(imm as i64 as u64);
            write_mem(hart, sys, vaddr, width, hart.reg(rs2))?;
            Ok(Flow::Next)
        }

        Op::Alu { op, word, rd, rs1, rs2 } => {
            hart.set_reg(rd, alu(op, word, hart.reg(rs1), hart.reg(rs2)));
            Ok(Flow::Next)
        }
        Op::AluImm { op, word, rd, rs1, imm } => {
            hart.set_reg(rd, alu(op, word, hart.reg(rs1), imm as i64 as u64));
            Ok(Flow::Next)
        }
        Op::Mul { op, word, rd, rs1, rs2 } => {
            hart.set_reg(rd, mul(op, word, hart.reg(rs1), hart.reg(rs2)));
            Ok(Flow::Next)
        }

        Op::Lr { width, rd, rs1 } => {
            let vaddr = hart.reg(rs1);
            if vaddr & width.mask() != 0 {
                return Err(Trap::new(EXC_LOAD_MISALIGNED, vaddr));
            }
            // LR/SC always take the cold path (coherence-visible).
            let paddr = cold_data_access(hart, sys, vaddr, false)?;
            let raw = phys_read(sys, paddr, width);
            if let Some(t) = sys.trace.as_mut() {
                t.record_mem(paddr, false, hart.id as u8);
            }
            hart.set_reg(rd, sext_load(raw, width, true));
            if sys.reservations[hart.id].is_none() {
                sys.active_reservations += 1;
            }
            sys.reservations[hart.id] = Some((paddr, raw));
            Ok(Flow::Next)
        }
        Op::Sc { width, rd, rs1, rs2 } => {
            let vaddr = hart.reg(rs1);
            if vaddr & width.mask() != 0 {
                return Err(Trap::new(EXC_STORE_MISALIGNED, vaddr));
            }
            let paddr = cold_data_access(hart, sys, vaddr, true)?;
            let success = match sys.reservations[hart.id] {
                Some((addr, loaded)) if addr == paddr => {
                    if sys.parallel {
                        // Parallel mode: commit via host compare-and-swap
                        // against the LR-observed value (ABA-tolerant, as
                        // on real hardware with address-only reservations).
                        match width {
                            MemWidth::W => sys
                                .phys
                                .cas_u32(paddr, loaded as u32, hart.reg(rs2) as u32)
                                .is_ok(),
                            _ => sys.phys.cas_u64(paddr, loaded, hart.reg(rs2)).is_ok(),
                        }
                    } else {
                        // Lockstep: the reservation table is authoritative —
                        // intervening stores cleared it.
                        phys_write(sys, paddr, width, hart.reg(rs2));
                        true
                    }
                }
                _ => false,
            };
            if success {
                sys.clear_reservations(paddr, hart.id);
                if let Some(t) = sys.trace.as_mut() {
                    t.record_mem(paddr, true, hart.id as u8);
                }
            }
            if sys.reservations[hart.id].take().is_some() {
                sys.active_reservations -= 1;
            }
            hart.set_reg(rd, !success as u64);
            Ok(Flow::Next)
        }
        Op::Amo { op, width, rd, rs1, rs2 } => {
            let vaddr = hart.reg(rs1);
            if vaddr & width.mask() != 0 {
                return Err(Trap::new(EXC_STORE_MISALIGNED, vaddr));
            }
            let paddr = cold_data_access(hart, sys, vaddr, true)?;
            if DeviceBus::is_mmio(paddr) {
                // AMO on MMIO: read-modify-write through the bus.
                let now = hart.now();
                let old = sys.bus.read(paddr, width.bytes(), now);
                let new = amo_compute(op, width, old, hart.reg(rs2));
                sys.bus.write(paddr, new, width.bytes());
                hart.set_reg(rd, sext_load(old, width, true));
                return Ok(Flow::Next);
            }
            let old = if sys.parallel {
                // Host-atomic read-modify-write loop.
                match width {
                    MemWidth::W => loop {
                        let cur = sys.phys.load_acq_u32(paddr);
                        let new = amo_compute(op, width, cur as u64, hart.reg(rs2)) as u32;
                        if sys.phys.cas_u32(paddr, cur, new).is_ok() {
                            break cur as u64;
                        }
                    },
                    _ => loop {
                        let cur = sys.phys.load_acq_u64(paddr);
                        let new = amo_compute(op, width, cur, hart.reg(rs2));
                        if sys.phys.cas_u64(paddr, cur, new).is_ok() {
                            break cur;
                        }
                    },
                }
            } else {
                let old = phys_read(sys, paddr, width);
                let new = amo_compute(op, width, old, hart.reg(rs2));
                sys.clear_reservations(paddr, hart.id);
                phys_write(sys, paddr, width, new);
                old
            };
            if let Some(t) = sys.trace.as_mut() {
                t.record_mem(paddr, true, hart.id as u8);
            }
            hart.set_reg(rd, sext_load(old, width, true));
            Ok(Flow::Next)
        }

        Op::Csr { op, imm_form, rd, rs1, csr } => {
            let src = if imm_form { rs1 as u64 } else { hart.reg(rs1) };
            let time = sys.bus.clint.mtime(hart.now());
            // Reads of the SIMSTATS CSR reflect live L0 counters.
            let old = if csr == CSR_SIMSTATS {
                let (acc, miss) = sys.l0[hart.id].d.stats();
                (acc & 0xffff_ffff) | (miss << 32)
            } else if csr == CSR_SIMCTRL {
                sys.simctrl_state
            } else {
                hart.csr_read(csr, time)?
            };
            let write_back = match op {
                CsrOp::Rw => Some(src),
                CsrOp::Rs => {
                    if rs1 == 0 {
                        None
                    } else {
                        Some(old | src)
                    }
                }
                CsrOp::Rc => {
                    if rs1 == 0 {
                        None
                    } else {
                        Some(old & !src)
                    }
                }
            };
            if let Some(v) = write_back {
                hart.csr_write(csr, v)?;
            }
            hart.set_reg(rd, old);
            Ok(Flow::Next)
        }

        Op::Fence => Ok(Flow::Next),
        Op::FenceI => {
            hart.effects.fence_i = true;
            Ok(Flow::Next)
        }
        Op::Ecall => {
            let cause = match hart.prv {
                Priv::User => EXC_ECALL_U,
                Priv::Supervisor => EXC_ECALL_S,
                Priv::Machine => EXC_ECALL_M,
            };
            Err(Trap::new(cause, 0))
        }
        Op::Ebreak => Err(Trap::new(EXC_BREAKPOINT, pc)),
        Op::Mret => {
            if hart.prv != Priv::Machine {
                return Err(Trap::new(EXC_ILLEGAL, 0));
            }
            Ok(Flow::Jump(hart.mret()))
        }
        Op::Sret => {
            if hart.prv < Priv::Supervisor {
                return Err(Trap::new(EXC_ILLEGAL, 0));
            }
            Ok(Flow::Jump(hart.sret()))
        }
        Op::Wfi => {
            if hart.prv == Priv::User {
                return Err(Trap::new(EXC_ILLEGAL, 0));
            }
            Ok(Flow::Wfi)
        }
        Op::SfenceVma { .. } => {
            if hart.prv < Priv::Supervisor {
                return Err(Trap::new(EXC_ILLEGAL, 0));
            }
            hart.effects.sfence = true;
            Ok(Flow::Next)
        }
    }
}

// ---------------------------------------------------------------------------
// Instruction fetch
// ---------------------------------------------------------------------------

/// Fetch up to 4 bytes at `pc`, using the L0 I-cache fast path; handles the
/// paper's cross-page case (a 4-byte instruction spanning two pages) by
/// translating both halves.
pub fn fetch_raw(hart: &mut Hart, sys: &mut System, pc: u64) -> Result<u32, Trap> {
    if pc & 1 != 0 {
        return Err(Trap::new(EXC_INSN_MISALIGNED, pc));
    }
    let lo = fetch_half(hart, sys, pc)?;
    if crate::isa::decode::inst_len(lo) == 2 {
        return Ok(lo as u32);
    }
    let hi = fetch_half(hart, sys, pc + 2)?;
    Ok((lo as u32) | ((hi as u32) << 16))
}

/// Fetch one halfword of instruction memory.
pub fn fetch_half(hart: &mut Hart, sys: &mut System, pc: u64) -> Result<u16, Trap> {
    let paddr = if sys.force_cold {
        cold_fetch(hart, sys, pc)?
    } else {
        match sys.l0[hart.id].i.lookup(pc) {
            Some(p) => p,
            None => cold_fetch(hart, sys, pc)?,
        }
    };
    Ok(sys.phys.read_u16(paddr))
}

/// Cold path for instruction fetch.
#[cold]
pub fn cold_fetch(hart: &mut Hart, sys: &mut System, pc: u64) -> Result<u64, Trap> {
    let ctx = hart.mmu_fetch_ctx();
    let tr = translate(&sys.phys, &ctx, pc, AccessKind::Execute)
        .map_err(|pf| page_fault_trap(pf, pc))?;
    if !sys.phys.contains(tr.paddr, 4) {
        return Err(Trap::new(EXC_INSN_ACCESS, pc));
    }
    let cold = sys.model.fetch_access(&mut sys.l0, hart.id, pc, &tr);
    hart.pending += cold.cycles;
    if cold.install.is_some() {
        sys.l0[hart.id].i.insert(pc, tr.paddr);
    }
    Ok(tr.paddr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::DRAM_BASE;

    fn setup() -> (Hart, System) {
        let mut hart = Hart::new(0);
        hart.pc = DRAM_BASE;
        let sys = System::new(1, 1 << 20);
        (hart, sys)
    }

    fn run(hart: &mut Hart, sys: &mut System, op: Op) -> Flow {
        exec_op(hart, sys, &op, hart.pc, hart.pc + 4).unwrap()
    }

    #[test]
    fn alu_basic() {
        let (mut h, mut s) = setup();
        h.set_reg(1, 5);
        h.set_reg(2, 7);
        run(&mut h, &mut s, Op::Alu { op: AluOp::Add, word: false, rd: 3, rs1: 1, rs2: 2 });
        assert_eq!(h.reg(3), 12);
        run(&mut h, &mut s, Op::AluImm { op: AluOp::Add, word: true, rd: 4, rs1: 1, imm: -6 });
        assert_eq!(h.reg(4), (-1i64) as u64);
    }

    #[test]
    fn word_ops_sign_extend() {
        let (mut h, mut s) = setup();
        h.set_reg(1, 0x7fff_ffff);
        run(&mut h, &mut s, Op::AluImm { op: AluOp::Add, word: true, rd: 2, rs1: 1, imm: 1 });
        assert_eq!(h.reg(2), 0xffff_ffff_8000_0000);
        h.set_reg(3, 0xffff_ffff_8000_0000);
        run(&mut h, &mut s, Op::AluImm { op: AluOp::Srl, word: true, rd: 4, rs1: 3, imm: 4 });
        assert_eq!(h.reg(4), 0x0800_0000);
        run(&mut h, &mut s, Op::AluImm { op: AluOp::Sra, word: true, rd: 5, rs1: 3, imm: 4 });
        assert_eq!(h.reg(5), 0xffff_ffff_f800_0000);
    }

    #[test]
    fn mul_div_edge_cases() {
        let (mut h, mut s) = setup();
        h.set_reg(1, u64::MAX); // -1
        h.set_reg(2, 0);
        run(&mut h, &mut s, Op::Mul { op: MulOp::Div, word: false, rd: 3, rs1: 1, rs2: 2 });
        assert_eq!(h.reg(3), u64::MAX); // div by zero -> -1
        run(&mut h, &mut s, Op::Mul { op: MulOp::Rem, word: false, rd: 4, rs1: 1, rs2: 2 });
        assert_eq!(h.reg(4), u64::MAX); // rem by zero -> dividend
        h.set_reg(5, i64::MIN as u64);
        h.set_reg(6, u64::MAX);
        run(&mut h, &mut s, Op::Mul { op: MulOp::Div, word: false, rd: 7, rs1: 5, rs2: 6 });
        assert_eq!(h.reg(7), i64::MIN as u64); // overflow
        // mulh
        h.set_reg(8, u64::MAX);
        h.set_reg(9, u64::MAX);
        run(&mut h, &mut s, Op::Mul { op: MulOp::Mulhu, word: false, rd: 10, rs1: 8, rs2: 9 });
        assert_eq!(h.reg(10), u64::MAX - 1);
        run(&mut h, &mut s, Op::Mul { op: MulOp::Mulh, word: false, rd: 11, rs1: 8, rs2: 9 });
        assert_eq!(h.reg(11), 0); // (-1)*(-1) = 1, high = 0
    }

    #[test]
    fn load_store_roundtrip() {
        let (mut h, mut s) = setup();
        h.set_reg(1, DRAM_BASE + 0x100);
        h.set_reg(2, 0xdead_beef_cafe_babe);
        run(&mut h, &mut s, Op::Store { width: MemWidth::D, rs1: 1, rs2: 2, imm: 8 });
        run(&mut h, &mut s, Op::Load { width: MemWidth::D, signed: true, rd: 3, rs1: 1, imm: 8 });
        assert_eq!(h.reg(3), 0xdead_beef_cafe_babe);
        // signed byte load
        run(&mut h, &mut s, Op::Load { width: MemWidth::B, signed: true, rd: 4, rs1: 1, imm: 8 });
        assert_eq!(h.reg(4), 0xffff_ffff_ffff_ffbe);
        // unsigned halfword
        run(&mut h, &mut s, Op::Load { width: MemWidth::H, signed: false, rd: 5, rs1: 1, imm: 8 });
        assert_eq!(h.reg(5), 0xbabe);
    }

    #[test]
    fn l0_fast_path_used_on_second_access() {
        let (mut h, mut s) = setup();
        h.set_reg(1, DRAM_BASE);
        run(&mut h, &mut s, Op::Load { width: MemWidth::W, signed: true, rd: 2, rs1: 1, imm: 0 });
        let (acc1, miss1) = s.l0[0].d.stats();
        run(&mut h, &mut s, Op::Load { width: MemWidth::W, signed: true, rd: 2, rs1: 1, imm: 4 });
        let (acc2, miss2) = s.l0[0].d.stats();
        assert_eq!(acc2, acc1 + 1);
        assert_eq!(miss2, miss1, "second access within the line must hit L0");
    }

    #[test]
    fn branches() {
        let (mut h, mut s) = setup();
        h.set_reg(1, 1);
        let f = run(&mut h, &mut s, Op::Branch { cond: BrCond::Ne, rs1: 1, rs2: 0, imm: -8 });
        assert_eq!(f, Flow::Taken);
        let f = run(&mut h, &mut s, Op::Branch { cond: BrCond::Eq, rs1: 1, rs2: 0, imm: -8 });
        assert_eq!(f, Flow::Next);
        let f = run(&mut h, &mut s, Op::Jal { rd: 1, imm: 16 });
        assert_eq!(f, Flow::Jump(h.pc + 16));
        assert_eq!(h.reg(1), h.pc + 4);
        h.set_reg(2, 0x8000_0101);
        let f = run(&mut h, &mut s, Op::Jalr { rd: 0, rs1: 2, imm: 2 });
        assert_eq!(f, Flow::Jump(0x8000_0102)); // low bit cleared
    }

    #[test]
    fn lr_sc_success_and_failure() {
        let (mut h, mut s) = setup();
        let addr = DRAM_BASE + 0x200;
        s.phys.write_u64(addr, 77);
        h.set_reg(1, addr);
        h.set_reg(2, 99);
        run(&mut h, &mut s, Op::Lr { width: MemWidth::D, rd: 3, rs1: 1 });
        assert_eq!(h.reg(3), 77);
        run(&mut h, &mut s, Op::Sc { width: MemWidth::D, rd: 4, rs1: 1, rs2: 2 });
        assert_eq!(h.reg(4), 0, "SC must succeed");
        assert_eq!(s.phys.read_u64(addr), 99);
        // SC without reservation fails.
        run(&mut h, &mut s, Op::Sc { width: MemWidth::D, rd: 5, rs1: 1, rs2: 2 });
        assert_eq!(h.reg(5), 1);
    }

    #[test]
    fn store_by_other_hart_breaks_reservation() {
        let mut s = System::new(2, 1 << 20);
        let mut h0 = Hart::new(0);
        let mut h1 = Hart::new(1);
        let addr = DRAM_BASE + 0x300;
        h0.set_reg(1, addr);
        h1.set_reg(1, addr);
        h1.set_reg(2, 5);
        exec_op(&mut h0, &mut s, &Op::Lr { width: MemWidth::D, rd: 3, rs1: 1 }, 0, 4).unwrap();
        exec_op(&mut h1, &mut s, &Op::Store { width: MemWidth::D, rs1: 1, rs2: 2, imm: 0 }, 0, 4)
            .unwrap();
        exec_op(&mut h0, &mut s, &Op::Sc { width: MemWidth::D, rd: 4, rs1: 1, rs2: 3 }, 0, 4)
            .unwrap();
        assert_eq!(h0.reg(4), 1, "SC must fail after intervening store");
        assert_eq!(s.phys.read_u64(addr), 5);
    }

    #[test]
    fn amo_ops() {
        let (mut h, mut s) = setup();
        let addr = DRAM_BASE + 0x400;
        s.phys.write_u32(addr, 10);
        h.set_reg(1, addr);
        h.set_reg(2, 32);
        run(&mut h, &mut s, Op::Amo { op: AmoOp::Add, width: MemWidth::W, rd: 3, rs1: 1, rs2: 2 });
        assert_eq!(h.reg(3), 10);
        assert_eq!(s.phys.read_u32(addr), 42);
        h.set_reg(2, 7);
        run(&mut h, &mut s, Op::Amo { op: AmoOp::Swap, width: MemWidth::W, rd: 4, rs1: 1, rs2: 2 });
        assert_eq!(h.reg(4), 42);
        assert_eq!(s.phys.read_u32(addr), 7);
        // amomax signed on negative
        s.phys.write_u32(addr, (-5i32) as u32);
        h.set_reg(2, 3);
        run(&mut h, &mut s, Op::Amo { op: AmoOp::Max, width: MemWidth::W, rd: 5, rs1: 1, rs2: 2 });
        assert_eq!(h.reg(5), (-5i64) as u64);
        assert_eq!(s.phys.read_u32(addr), 3);
    }

    #[test]
    fn csr_roundtrip_and_counters() {
        let (mut h, mut s) = setup();
        h.set_reg(1, 0x1234);
        run(&mut h, &mut s, Op::Csr { op: CsrOp::Rw, imm_form: false, rd: 2, rs1: 1, csr: CSR_MSCRATCH });
        run(&mut h, &mut s, Op::Csr { op: CsrOp::Rs, imm_form: false, rd: 3, rs1: 0, csr: CSR_MSCRATCH });
        assert_eq!(h.reg(3), 0x1234);
        // mcycle read reflects pending cycles
        h.cycle = 100;
        h.pending = 5;
        run(&mut h, &mut s, Op::Csr { op: CsrOp::Rs, imm_form: false, rd: 4, rs1: 0, csr: CSR_MCYCLE });
        assert_eq!(h.reg(4), 105);
    }

    #[test]
    fn ecall_raises_per_privilege() {
        let (mut h, mut s) = setup();
        let pc = h.pc;
        let e = exec_op(&mut h, &mut s, &Op::Ecall, pc, pc + 4).unwrap_err();
        assert_eq!(e.cause, EXC_ECALL_M);
        h.prv = Priv::User;
        let e = exec_op(&mut h, &mut s, &Op::Ecall, pc, pc + 4).unwrap_err();
        assert_eq!(e.cause, EXC_ECALL_U);
    }

    #[test]
    fn mmio_store_reaches_uart() {
        let (mut h, mut s) = setup();
        h.set_reg(1, super::super::dev::UART_BASE);
        h.set_reg(2, b'A' as u64);
        run(&mut h, &mut s, Op::Store { width: MemWidth::B, rs1: 1, rs2: 2, imm: 0 });
        assert_eq!(s.bus.uart.output, vec![b'A']);
        // MMIO accesses charge latency and never install into L0
        assert!(h.pending >= MMIO_LATENCY);
        assert!(s.l0[0].d.lookup_read(super::super::dev::UART_BASE).is_none());
    }

    #[test]
    fn fetch_basic_and_compressed() {
        let (mut h, mut s) = setup();
        // ecall (4 bytes) at DRAM_BASE, c.li a0,1 (2 bytes) at +4
        s.phys.write_u32(DRAM_BASE, 0x0000_0073);
        s.phys.write_u16(DRAM_BASE + 4, 0x4505);
        assert_eq!(fetch_raw(&mut h, &mut s, DRAM_BASE).unwrap(), 0x0000_0073);
        assert_eq!(fetch_raw(&mut h, &mut s, DRAM_BASE + 4).unwrap(), 0x4505);
    }

    #[test]
    fn illegal_raises() {
        let (mut h, mut s) = setup();
        let pc = h.pc;
        let e = exec_op(&mut h, &mut s, &Op::Illegal { raw: 0xffff_ffff }, pc, pc + 4)
            .unwrap_err();
        assert_eq!(e.cause, EXC_ILLEGAL);
    }
}
