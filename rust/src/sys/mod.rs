//! Full-system substrate: hart state, instruction semantics, devices,
//! environment-call emulation (SBI / Linux syscalls), and loaders.

pub mod dev;
pub mod exec;
pub mod hart;
pub mod loader;
pub mod sbi;
pub mod snapshot;
pub mod syscall;

pub use hart::{Hart, SideEffects, Trap};
pub use snapshot::SystemSnapshot;

use crate::analytics::trace::TraceCapture;
use crate::mem::l0::L0Set;
use crate::mem::{AtomicModel, MemTiming, MemoryModel, PhysMem, DRAM_BASE};
use dev::DeviceBus;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

/// How ECALL is handled outside the guest (paper §3.5: user-level,
/// supervisor-level and machine-level simulation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EcallMode {
    /// Full machine-level simulation: every ecall traps into guest code.
    Machine,
    /// Supervisor-level simulation: ecalls from S-mode are emulated as SBI
    /// calls; M-mode is not simulated.
    Sbi,
    /// User-level simulation: ecalls from U-mode are emulated as Linux
    /// syscalls.
    Syscall,
}

/// Shared system state: everything outside per-hart architectural state.
///
/// Held by the execution engines alongside the `Hart` vector; memory models
/// receive `&mut [L0Set]` so coherence events can flush *other* harts' L0
/// caches (Fig 3 / §3.4.3).
pub struct System {
    pub phys: Arc<PhysMem>,
    pub bus: DeviceBus,
    pub model: Box<dyn MemoryModel>,
    pub l0: Vec<L0Set>,
    /// LR reservations per hart: (physical address, loaded value). The
    /// value is used by SC in parallel mode (compare-and-swap commit).
    pub reservations: Vec<Option<(u64, u64)>>,
    /// Number of live reservations (hot-path fast check).
    pub active_reservations: u32,
    /// Pending inter-processor interrupt bits per hart (posted by SBI
    /// emulation, folded into `mip` at the next interrupt poll).
    pub ipi: Vec<u64>,
    /// Program break for user-level syscall emulation.
    pub brk: u64,
    /// Bump pointer for emulated anonymous mmap.
    pub mmap_top: u64,
    pub ecall_mode: EcallMode,
    /// Simulation exit requested (SIMIO write / exit syscall / SBI
    /// shutdown) with exit code.
    pub exit: Option<u64>,
    /// Packed current model configuration, readable via the SIMCTRL CSR.
    pub simctrl_state: u64,
    /// Optional analytics trace capture.
    pub trace: Option<TraceCapture>,
    /// Observability layer (event timeline, telemetry; DESIGN.md §12).
    /// `None` unless `--trace-out`/`--stats-every`/`profile` armed it —
    /// the single cold branch the disabled hot path pays.
    pub obs: Option<Box<crate::obs::Obs>>,
    /// Bypass the L0 fast path entirely, invoking the memory model on
    /// every access (paper §3.4.1's exact-replacement escape hatch; also
    /// the A2 ablation and the gem5-like baseline's behaviour).
    pub force_cold: bool,
    /// Functional-parallel execution mode (§3.5): other harts run in other
    /// host threads; AMO/LR/SC must use host atomics.
    pub parallel: bool,
    /// Cross-thread exit flag for parallel mode (u64::MAX = running).
    pub shared_exit: Option<Arc<AtomicU64>>,
    /// Cross-thread engine-switch flag for parallel mode (u64::MAX = no
    /// request; otherwise the raw SIMCTRL value).
    pub shared_switch: Option<Arc<AtomicU64>>,
    /// Pending engine-switch request (raw SIMCTRL value). Engines return
    /// [`crate::engine::ExitReason::SwitchRequest`] when they observe it.
    pub switch_request: Option<u64>,
    /// SIMCTRL engine code of the engine currently driving this system
    /// (`isa::csr::SIMCTRL_ENGINE_*`): a guest SIMCTRL write requesting
    /// this code is a no-op, any other valid code stops the engine with a
    /// switch request.
    pub engine_code: u64,
    /// A SIMCTRL write with globally scoped fields (memory model / line
    /// size) happened: the raw value, for the engine driver to propagate
    /// to sibling shard cores (immediately under a shared system, at the
    /// next quantum boundary across shard-private systems). Meaningless —
    /// and ignored — under the single-core engines, whose own core already
    /// covers every hart.
    pub pending_broadcast: Option<u64>,
    /// This system's memory model must record cross-shard bus events
    /// (threaded sharded execution). Kept at the system level so a
    /// runtime model switch ([`System::set_model`]) re-arms the fresh
    /// model instead of silently dropping the mailbox traffic.
    pub record_bus_events: bool,
    /// Timing parameters used when SIMCTRL constructs new memory models.
    pub timing: MemTiming,
    pub num_harts: usize,
}

/// Default program break: the DRAM midpoint (guest memory-layout split
/// shared by every engine's `System` seeding).
pub fn default_brk(dram_size: u64) -> u64 {
    DRAM_BASE + dram_size / 2
}

/// Default anonymous-mmap bump base: the top quarter of DRAM.
pub fn default_mmap_top(dram_size: u64) -> u64 {
    DRAM_BASE + dram_size * 3 / 4
}

impl System {
    /// Build a system with the given DRAM size and the Atomic memory model.
    pub fn new(num_harts: usize, dram_size: usize) -> System {
        System::with_model(num_harts, dram_size, Box::new(AtomicModel))
    }

    pub fn with_model(
        num_harts: usize,
        dram_size: usize,
        model: Box<dyn MemoryModel>,
    ) -> System {
        System::with_shared_phys(num_harts, Arc::new(PhysMem::new(DRAM_BASE, dram_size)), model)
    }

    /// Build a system over pre-existing (possibly shared) guest DRAM —
    /// the parallel functional mode gives every hart thread its own
    /// `System` over one shared `PhysMem`.
    pub fn with_shared_phys(
        num_harts: usize,
        phys: Arc<PhysMem>,
        model: Box<dyn MemoryModel>,
    ) -> System {
        let dram_size = phys.size() as usize;
        System {
            phys,
            bus: DeviceBus::new(num_harts),
            model,
            l0: (0..num_harts).map(|_| L0Set::new(6)).collect(),
            reservations: vec![None; num_harts],
            active_reservations: 0,
            ipi: vec![0; num_harts],
            brk: default_brk(dram_size as u64),
            mmap_top: default_mmap_top(dram_size as u64),
            ecall_mode: EcallMode::Sbi,
            exit: None,
            simctrl_state: 0,
            trace: None,
            obs: None,
            force_cold: false,
            parallel: false,
            shared_exit: None,
            shared_switch: None,
            switch_request: None,
            engine_code: crate::isa::csr::SIMCTRL_ENGINE_LOCKSTEP,
            pending_broadcast: None,
            record_bus_events: false,
            timing: MemTiming::default(),
            num_harts,
        }
    }

    /// Record a guest request to switch execution engines (SIMCTRL engine
    /// field, §3.5 extended). In parallel mode the request is also posted
    /// on the cross-thread flag so sibling hart threads stop too.
    pub fn request_engine_switch(&mut self, value: u64) {
        self.switch_request = Some(value);
        if let Some(flag) = &self.shared_switch {
            use std::sync::atomic::Ordering;
            let _ = flag.compare_exchange(u64::MAX, value, Ordering::SeqCst, Ordering::SeqCst);
        }
    }

    /// Replace the memory model at runtime (§3.5): flushes all L0 caches
    /// and the old model's state. The sharded bus-recording mode carries
    /// over to the fresh model.
    pub fn set_model(&mut self, model: Box<dyn MemoryModel>) {
        self.model.flush_all(&mut self.l0);
        self.model = model;
        if self.record_bus_events {
            self.model.set_bus_recording(true);
        }
        for set in &mut self.l0 {
            set.clear();
        }
    }

    /// Reconfigure the L0 cache-line size (§3.5), flushing.
    pub fn set_line_shift(&mut self, line_shift: u32) {
        for set in &mut self.l0 {
            set.d.set_line_shift(line_shift);
            set.i.set_line_shift(line_shift);
        }
    }

    /// Clear another hart's (or any hart's) LR reservation if it covers
    /// `paddr` — invoked on stores so contended LR/SC stays atomic.
    #[inline]
    pub fn clear_reservations(&mut self, paddr: u64, except: usize) {
        if self.active_reservations == 0 {
            return;
        }
        for (h, r) in self.reservations.iter_mut().enumerate() {
            if h != except {
                if let Some((addr, _)) = *r {
                    // Reserve at 64-byte granularity (a cache line).
                    if addr >> 6 == paddr >> 6 {
                        *r = None;
                        self.active_reservations -= 1;
                    }
                }
            }
        }
    }
}

/// Dispatch an ECALL to the configured emulation layer (§3.5).
/// Returns `true` if emulated — the engine then resumes after the ecall —
/// or `false` to deliver the trap to guest code.
pub fn handle_ecall(hart: &mut Hart, sys: &mut System) -> bool {
    match sys.ecall_mode {
        EcallMode::Machine => false,
        EcallMode::Sbi => sbi::handle_sbi(hart, sys),
        EcallMode::Syscall => {
            if hart.prv == crate::isa::csr::Priv::User {
                syscall::handle_syscall(hart, sys)
            } else {
                sbi::handle_sbi(hart, sys)
            }
        }
    }
}
